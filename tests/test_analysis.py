"""repro.analysis: the static-analysis pass keeps its teeth.

Three layers of coverage, same philosophy as the PR 5 interpret
registry (a gate nobody exercises is a gate that silently rots):

  fixtures   every registered rule has a POSITIVE snippet its check
             must flag and a NEGATIVE snippet it must not — the
             near-miss shape that separates detection from pattern-
             matching on spelling.
  meta       the fixture table is asserted against the live rule
             registry, so registering a rule without fixtures fails
             here, not in review.
  self-run   ``src/`` is clean modulo the recorded allows, and the
             known while-in-shard_map engine site is DETECTED then
             suppressed (proving cross-module detection on real
             code, not just on fixtures).
"""

import os
import textwrap

import pytest

from repro.analysis import Project, all_rules, run

pytestmark = pytest.mark.tier1

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _fix(src: str) -> str:
    return textwrap.dedent(src).lstrip()


# rule-id -> {positive: {path: src}, negative: {path: src}} — paths
# are virtual but repo-shaped so path-scoped rules behave as on disk
FIXTURES = {
    "guarded-by": {
        "positive": {"repro/fx/guard_pos.py": _fix("""
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0  # guarded_by: _lock

                def bump(self):
                    self._n += 1
            """)},
        "negative": {"repro/fx/guard_neg.py": _fix("""
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0  # guarded_by: _lock
                    self._free = 0

                def bump(self):
                    with self._lock:
                        self._n += 1
                    self._free += 1
            """)},
    },
    "clock-discipline": {
        "positive": {"repro/fx/clock_pos.py": _fix("""
            import time

            def elapsed(t0):
                return time.monotonic() - t0
            """)},
        "negative": {"repro/fx/clock_neg.py": _fix("""
            import time

            from repro import obs

            def elapsed(t0):
                time.sleep(0.0)  # sleep is not a clock READ
                return obs.now() - t0
            """)},
    },
    "jax-while-shard-map": {
        # the hard shape: the while_loop is NOT lexical in the closure
        # — it hides one call away, exactly like engine.py ->
        # core/search.search_impl
        "positive": {
            "repro/fx/wsm_search.py": _fix("""
                import jax

                def refine(state):
                    return jax.lax.while_loop(
                        lambda c: c < 3, lambda c: c + 1, state)
                """),
            "repro/fx/wsm_engine.py": _fix("""
                from repro import compat
                from repro.fx.wsm_search import refine

                def local(q):
                    return refine(q)

                fn = compat.shard_map(local, mesh=None, in_specs=(),
                                      out_specs=())
                """),
        },
        "negative": {
            "repro/fx/wsm_neg.py": _fix("""
                import jax
                from repro import compat

                def refine(state):
                    # while_loop OUTSIDE any shard_map closure: legal
                    return jax.lax.while_loop(
                        lambda c: c < 3, lambda c: c + 1, state)

                def local(q):
                    return q * 2

                fn = compat.shard_map(local, mesh=None, in_specs=(),
                                      out_specs=())
                """),
        },
    },
    "jax-topk-on-topk": {
        "positive": {"repro/fx/tot_pos.py": _fix("""
            import jax

            def select(dists, kk):
                neg, _ = jax.lax.top_k(-dists, kk)
                thr = -neg[:, -1:]
                _, pos = jax.lax.top_k(dists * thr, kk)
                return pos
            """)},
        "negative": {"repro/fx/tot_neg.py": _fix("""
            import jax
            import jax.numpy as jnp

            def select(dists, ids, kk):
                # argsort-permute + ONE top_k: the shared-pool idiom
                order = jnp.argsort(ids)
                neg, pos = jax.lax.top_k(-dists[:, order], kk)
                return -neg, pos
            """)},
    },
    "jax-int32-topk": {
        "positive": {"repro/fx/i32_pos.py": _fix("""
            import jax
            import jax.numpy as jnp

            def pick(ids, kk):
                keys = ids.astype(jnp.int32)
                return jax.lax.top_k(keys, kk)
            """)},
        "negative": {"repro/fx/i32_neg.py": _fix("""
            import jax
            import jax.numpy as jnp

            def pick(ids, kk):
                keys = ids.astype(jnp.float32)
                return jax.lax.top_k(keys, kk)
            """)},
    },
    "jax-host-sync-in-jit": {
        "positive": {"repro/fx/sync_pos.py": _fix("""
            import jax
            import numpy as np

            @jax.jit
            def step(x):
                y = x + 1
                host = np.asarray(y)
                return host.sum(), y[0].item()
            """)},
        "negative": {"repro/fx/sync_neg.py": _fix("""
            import jax
            import numpy as np

            TABLE = [1, 2, 3]

            @jax.jit
            def step(x):
                # np on STATIC module data at trace time is fine
                lut = np.asarray(TABLE)
                return x + lut.sum()

            def host_side(x):
                return np.asarray(x)  # not a jitted body
            """)},
    },
    "broad-except": {
        # reasonless broad handlers in all three spellings — including
        # a BARE noqa, which silences a linter without explaining the
        # boundary
        "positive": {"repro/fx/be_pos.py": _fix("""
            def risky():
                try:
                    return 1
                except Exception:
                    return None

            def risky2():
                try:
                    return 1
                except (ValueError, BaseException):  # noqa: BLE001
                    return None

            def risky3():
                try:
                    return 1
                except:
                    return None
            """)},
        "negative": {"repro/fx/be_neg.py": _fix("""
            def narrow():
                try:
                    return 1
                except ValueError:  # narrow handlers need no reason
                    return None

            def boundary():
                try:
                    return 1
                except Exception:  # supervisor restart boundary: any step fault must restart, not crash
                    return None

            def linted():
                try:
                    return 1
                except Exception:  # noqa: BLE001 — reason after the directive counts
                    return None
            """)},
    },
    "guarantee-kwargs": {
        # entry-point call with a loose guarantee kwarg fires; the
        # near-miss is the internal unpacked layer (search_impl) and
        # the typed spelling on a real entry point — both clean
        "positive": {"repro/fx/gkw_pos.py": _fix("""
            from repro.core import search as S

            def lookup(idx, q, store):
                a = S.search(idx, q, 5, epsilon=1.0)
                b = S.search_ooc(store, q, 5, delta=0.99,
                                 epsilon=0.5, cache_leaves=6)
                return a, b

            def served(engine, q):
                return engine.query(q, 5, nprobe=16)
            """)},
        "negative": {"repro/fx/gkw_neg.py": _fix("""
            from repro.core import guarantees as G
            from repro.core import search as S
            from repro.core.search import search_impl

            def lookup(idx, q, store):
                a = S.search(idx, q, 5, G.epsilon(1.0))
                b = S.search_ooc(store, q, 5,
                                 G.delta_epsilon(0.99, 0.5),
                                 cache_leaves=6)
                return a, b

            def internal(idx, q):
                # the unpacked layer legitimately takes the scalars
                return search_impl(idx, q, 5, delta=0.99,
                                   epsilon=1.0, nprobe=0)

            def served(engine, q):
                return engine.query(q, 5, G.ng(16))
            """)},
    },
    "engine-stats": {
        "positive": {"repro/fx/engstat_pos.py": _fix("""
            def degraded(engine, res):
                a = engine.last_ooc_stats
                b = getattr(engine, "last_ooc_stats", None)
                return a, b
            """)},
        "negative": {"repro/fx/engstat_neg.py": _fix("""
            def degraded(res):
                stats = getattr(res, "stats", None)
                return stats is not None and stats.degraded
            """)},
    },
    "stats-schema": {
        "positive": {"repro/fx/stats_pos.py": _fix("""
            def report(a, b, c):
                return {"bytes_read": a, "hits": b, "misses": c}
            """)},
        "negative": {"repro/fx/stats_neg.py": _fix("""
            def report(a, b):
                # < 3 schema fields: incidental overlap, not a stats
                # surface
                return {"bytes_read": a, "hits": b, "latency": 0.0}
            """)},
    },
}


# ------------------------------------------------------------- meta test
def test_every_rule_has_positive_and_negative_fixtures():
    """Registering a rule without fixture coverage fails HERE (the
    interpret-registry idiom: the meta test is what gives the fixture
    table teeth)."""
    assert set(FIXTURES) == set(all_rules())
    for rid, fx in FIXTURES.items():
        assert fx["positive"] and fx["negative"], rid


@pytest.mark.parametrize("rid", sorted(FIXTURES))
def test_positive_fixture_fires(rid):
    report = run(Project.from_sources(FIXTURES[rid]["positive"]), [rid])
    assert report.findings, f"{rid}: positive fixture produced nothing"
    assert all(f.rule == rid for f in report.findings)


@pytest.mark.parametrize("rid", sorted(FIXTURES))
def test_negative_fixture_is_clean(rid):
    report = run(Project.from_sources(FIXTURES[rid]["negative"]), [rid])
    assert report.ok, [f.format() for f in report.findings]


# --------------------------------------------------------- suppressions
def _guard_pos_with_allow(reason: str) -> dict:
    src = FIXTURES["guarded-by"]["positive"]["repro/fx/guard_pos.py"]
    return {"repro/fx/guard_pos.py": src.replace(
        "self._n += 1\n",
        f"self._n += 1  # repro: allow[guarded-by] {reason}\n")}


def test_allow_with_reason_suppresses():
    report = run(Project.from_sources(_guard_pos_with_allow(
        "fixture: lock-free by design")), ["guarded-by"])
    assert report.ok
    assert len(report.suppressed) == 1
    finding, allow = report.suppressed[0]
    assert finding.rule == "guarded-by"
    assert allow.reason == "fixture: lock-free by design"


def test_allow_without_reason_is_an_error():
    report = run(Project.from_sources(_guard_pos_with_allow("")),
                 ["guarded-by"])
    assert [f.rule for f in report.findings] == ["allow-hygiene"]
    assert "without a reason" in report.findings[0].message


def test_unused_allow_is_an_error():
    report = run(Project.from_sources({"repro/fx/clean.py": _fix("""
        # repro: allow[guarded-by] nothing here needs this
        X = 1
        """)}), ["guarded-by"])
    assert [f.rule for f in report.findings] == ["allow-hygiene"]
    assert "unused" in report.findings[0].message


def test_allow_naming_unknown_rule_is_an_error():
    report = run(Project.from_sources({"repro/fx/typo.py": _fix("""
        X = 1  # repro: allow[guarded-bye] typo'd rule id
        """)}), ["guarded-by"])
    assert [f.rule for f in report.findings] == ["allow-hygiene"]
    assert "unknown rule" in report.findings[0].message


def test_allow_above_statement_covers_next_code_line():
    src = FIXTURES["guarded-by"]["positive"]["repro/fx/guard_pos.py"]
    src = src.replace(
        "        self._n += 1\n",
        "        # repro: allow[guarded-by] fixture: comment-above "
        "placement\n        self._n += 1\n")
    report = run(Project.from_sources({"repro/fx/g.py": src}),
                 ["guarded-by"])
    assert report.ok and len(report.suppressed) == 1


# ------------------------------------------------------------- self-run
@pytest.fixture(scope="module")
def src_report():
    return run(Project.from_paths([SRC]))


def test_src_is_clean_modulo_recorded_allows(src_report):
    assert src_report.ok, "\n".join(
        f.format() for f in src_report.findings)


def test_at_least_six_active_rules(src_report):
    assert len(src_report.rules_run) >= 6


def test_engine_shard_map_site_detected_then_suppressed(src_report):
    """The 0.4.37 while-in-shard_map engine site must be FOUND (the
    rule sees through engine.local -> search_impl) and then allowed
    with a reason — detection proven on real code."""
    hits = [(f, al) for f, al in src_report.suppressed
            if f.rule == "jax-while-shard-map"
            and f.path.endswith("core/engine.py")]
    assert hits, "engine.py shard_map site no longer detected"
    assert all(al.reason for _, al in hits)


def test_clock_rule_scoping_on_real_tree(src_report):
    """repro/obs/trace.py defines obs.now via time.perf_counter —
    exempt; no clock finding may point into repro/obs/."""
    for f, _ in src_report.suppressed:
        if f.rule == "clock-discipline":
            assert "/obs/" not in f.path
