"""Correctness of the §Perf optimization variants: they must change the
execution plan, never the math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.params import initialize

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def test_sequence_parallel_flag_is_numerically_identity():
    """On one device the constraints no-op; under a mesh they only move
    data — same math either way. Verify the flag leaves loss unchanged
    (trace-level identity on CPU)."""
    cfg = get_smoke_config("minitron-8b")
    cfg_sp = dataclasses.replace(cfg, sequence_parallel=True)
    params = initialize(M.model_specs(cfg), KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, _ = M.loss_fn(params, batch, cfg)
    l2, _ = M.loss_fn(params, batch, cfg_sp)
    np.testing.assert_allclose(float(l1), float(l2), atol=0, rtol=0)


def test_ring_cache_matches_full_cache_decode():
    """Token-by-token decode with ring-buffer local caches must produce
    the same logits as full-capacity caches (gemma2-family: alternating
    local/global)."""
    base = get_smoke_config("gemma2-2b")
    base = dataclasses.replace(
        base, param_dtype=jnp.float32, compute_dtype=jnp.float32,
        local_window=6)
    ring = dataclasses.replace(base, local_ring_cache=True)
    params = initialize(M.model_specs(base), KEY)
    b, steps = 2, 14

    def roll(cfg):
        from repro.models.params import initialize as init_p

        cache = init_p(M.decode_cache_specs(cfg, b, steps), KEY)
        cache = jax.tree_util.tree_map(jnp.zeros_like, cache)
        tok = jnp.zeros((b, 1), jnp.int32)
        outs = []
        key = KEY
        for t in range(steps):
            key, sub = jax.random.split(key)
            tok = jax.random.randint(sub, (b, 1), 0, cfg.vocab_size)
            logits, cache = M.decode_step(params, tok, cache,
                                          jnp.int32(t), cfg)
            outs.append(logits[:, 0])
        return jnp.stack(outs, 1)

    full = roll(base)
    rb = roll(ring)
    np.testing.assert_allclose(np.asarray(rb), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_ring_cache_capacity_is_window():
    cfg = dataclasses.replace(get_smoke_config("gemma2-2b"),
                              local_ring_cache=True, local_window=8)
    specs = M.decode_cache_specs(cfg, batch=2, seq=64)
    # sub0 = local layer, sub1 = global layer in the gemma2 pattern
    local_k = specs["blocks"]["sub0"]["k"]
    global_k = specs["blocks"]["sub1"]["k"]
    assert local_k.shape[2] == 8      # [G, B, cap, kv, hd]
    assert global_k.shape[2] == 64
