"""LeafSource conformance suite + engine out-of-core serving parity.

The refinement core (core/refine.py) is ONE loop body parameterized by
a LeafSource; this suite holds every implementation — ResidentSource
(HBM), CachedStoreSource (memmap + device leaf cache, f32/bf16) and
PQSource (ADC codes + exact re-rank) — to the same contract:

  gather      pool[gather_idx] decodes to the index's rows at row_idx
              wherever valid; validity matches the leaf extents.
  score       refine_step folds candidates into the running top-k
              exactly like the full-sort oracle.
  finalize    identity for lossless sources; the PQ re-rank reports
              exact distances for the returned ids.

Plus: the shared frontier emits the stable-argsort visit order through
tick/advance (the host-loop entry points), and DistributedEngine.query
over spill-built shards is bit-exact vs the resident engine path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import refine
from repro.core import search as S
from repro.core import IndexSpec, StoreSpec
from repro.core.engine import DistributedEngine
from repro.core.guarantees import Guarantee
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree
from repro.store import DeviceLeafCache
from repro.store.ooc import make_source

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def built(walk_data):
    return dstree.build(walk_data, leaf_cap=32)


@pytest.fixture(scope="module")
def built_bf16(walk_data):
    return dstree.build(walk_data, leaf_cap=32,
                        data_dtype=jnp.bfloat16)


@pytest.fixture(scope="module")
def queries_mod(walk_queries):
    return jnp.asarray(walk_queries)


def _store_source(idx, tmp_path_factory, codec):
    d = idx.save(str(tmp_path_factory.mktemp(f"src_{codec}")),
                 codec=codec)
    store = FrozenIndex.load(d, resident="summaries")
    cache = DeviceLeafCache(store, max(store.num_leaves, 8))
    return make_source(store, cache)


@pytest.fixture(scope="module")
def sources(built, built_bf16, tmp_path_factory):
    return {
        "resident": refine.ResidentSource(built),
        "store_f32": _store_source(built, tmp_path_factory, "f32"),
        "store_bf16": _store_source(built_bf16, tmp_path_factory,
                                    "bf16"),
        "store_pq": _store_source(built, tmp_path_factory, "pq"),
    }


def _index_of(name, src):
    return src.index if name == "resident" else src.store.resident


def _window(idx, b, v=3):
    """A deterministic [B, V] leaf window + ok mask with one masked
    slot and one duplicated leaf (the awkward cases)."""
    L = idx.num_leaves
    rng = np.random.default_rng(0)
    leaf = rng.integers(0, L, size=(b, v)).astype(np.int64)
    leaf[0, 1] = leaf[0, 0]          # duplicate within a lane
    if b > 1:
        leaf[1, 0] = leaf[0, 0]      # duplicate across lanes
    ok = np.ones((b, v), bool)
    ok[-1, -1] = False
    return leaf, ok


@pytest.mark.parametrize("name", ["resident", "store_f32",
                                  "store_bf16", "store_pq"])
def test_source_protocol_conformance(name, sources, queries_mod):
    src = sources[name]
    assert isinstance(src, refine.LeafSource)
    assert src.pq == (name == "store_pq")
    k = 5
    assert src.track_width(k) == (k * src.rerank if src.pq else k)
    ctx = src.query_ctx(queries_mod)
    assert ctx.qf.dtype == jnp.float32
    assert (ctx.luts is None) == (not src.pq)
    assert (ctx.norms is None) == src.pq


@pytest.mark.parametrize("name", ["resident", "store_f32",
                                  "store_bf16", "store_pq"])
def test_gather_contract(name, sources, queries_mod):
    """pool[gather_idx] == the leaf rows at row_idx (in the source's
    encoding) wherever valid; validity == leaf extents & ok."""
    src = sources[name]
    idx = _index_of(name, src)
    b = queries_mod.shape[0]
    leaf, ok = _window(idx, b)
    if name == "resident":
        g = src.gather(jnp.asarray(leaf, jnp.int32), jnp.asarray(ok))
    else:
        g = src.gather(leaf, ok)
    rows = np.asarray(g.pool)[np.asarray(g.gather_idx)]
    row_idx = np.asarray(g.row_idx)
    valid = np.asarray(g.valid)
    offs = np.asarray(idx.offsets)
    m = idx.max_leaf
    # validity: position inside the leaf extent AND slot usable
    sizes = (offs[leaf + 1] - offs[leaf])          # [B, V]
    pos = np.arange(m)[None, None, :]
    want_valid = ((pos < sizes[:, :, None]) & ok[:, :, None]) \
        .reshape(b, -1)
    np.testing.assert_array_equal(valid, want_valid)
    # row positions: the leaf-contiguous extent offsets
    want_idx = (offs[leaf][:, :, None] + pos).reshape(b, -1)
    np.testing.assert_array_equal(row_idx[valid], want_idx[valid])
    # encoded content: what the residency actually holds at those rows
    # (HBM data array, or the store's encoded payload — codes for pq)
    want_rows = np.asarray(src.index.data if name == "resident"
                           else src.store.mmap)
    np.testing.assert_array_equal(rows[valid],
                                  want_rows[row_idx[valid]])


@pytest.mark.parametrize("name", ["resident", "store_f32",
                                  "store_bf16"])
@pytest.mark.parametrize("share", [False, True])
def test_score_matches_full_sort_oracle(name, share, sources,
                                        queries_mod):
    """refine_step (both residencies, both scoring modes) == merge of
    exhaustively computed f32 distances over the same candidates."""
    src = sources[name]
    idx = _index_of(name, src)
    b = queries_mod.shape[0]
    k = 5
    leaf, ok = _window(idx, b)
    leaf_j, ok_j = jnp.asarray(leaf, jnp.int32), jnp.asarray(ok)
    g = src.gather(leaf_j if name == "resident" else leaf,
                   ok_j if name == "resident" else ok)
    ctx = src.query_ctx(queries_mod)
    top_d = jnp.full((b, k), jnp.inf)
    top_i = jnp.full((b, k), -1, jnp.int32)
    use_valid = refine.coop_mask(leaf_j, ok_j, g.valid) if share \
        else g.valid
    got_d, got_i = src.score(ctx, g, use_valid, top_d, top_i,
                             share=share)
    # oracle: exhaustive f32 distances + per-lane sort by (d, id)
    data = np.asarray(idx.data if name == "resident"
                      else src.store.mmap)
    ids_h = np.asarray(_index_of(name, src).ids
                       if name == "resident" else
                       src.store.resident.ids)
    row_idx = np.asarray(g.row_idx)
    valid = np.asarray(use_valid)
    qf = np.asarray(ctx.qf, np.float32)
    for lane in range(b):
        if share:
            rs = row_idx.reshape(-1)
            vs = valid.reshape(-1)
        else:
            rs = row_idx[lane]
            vs = valid[lane]
        cand = data[rs].astype(np.float32)
        d = ((cand - qf[lane]) ** 2).sum(1)
        d = np.where(vs, d, np.inf)
        cid = np.where(vs, ids_h[rs], -1)
        order = np.lexsort((cid, d))
        sel_d, sel_i = d[order[:k]], cid[order[:k]]
        gd = np.asarray(got_d[lane])
        gi = np.asarray(got_i[lane])
        finite = np.isfinite(sel_d)
        np.testing.assert_array_equal(gi[finite], sel_i[finite])
        # fused |q|^2-2qx+|x|^2 vs the oracle's direct difference form
        np.testing.assert_allclose(gd[finite], sel_d[finite],
                                   rtol=1e-4, atol=1e-4)


def test_coop_pq_score_matches_pre_fusion_step(sources, queries_mod):
    """refine_step's cooperative pq corner (now the fused
    ops.pq_adc_select selection + dedup merge) must stay bit-exact to
    the pre-fusion formulation — the full [B, R] pq_adc_batch matrix
    folded through topk_merge_unique — at the real PQSource call
    site, ids AND distances, placeholders included."""
    src = sources["store_pq"]
    store_res = src.store.resident
    b = queries_mod.shape[0]
    k = src.track_width(4)
    leaf, ok = _window(store_res, b)
    g = src.gather(leaf, ok)
    ctx = src.query_ctx(queries_mod)
    top_d = jnp.full((b, k), jnp.inf)
    top_i = jnp.full((b, k), -1, jnp.int32)
    use_valid = refine.coop_mask(jnp.asarray(leaf, jnp.int32),
                                 jnp.asarray(ok), g.valid)
    got_d, got_i = src.score(ctx, g, use_valid, top_d, top_i,
                             share=True)
    from repro.kernels import ops
    rows = g.pool[g.gather_idx.reshape(-1)]
    cand = jnp.where(use_valid, g.row_idx, -1).reshape(-1)
    d = ops.pq_adc_batch(rows, ctx.luts)
    d = jnp.where(use_valid.reshape(-1)[None, :], d, jnp.inf)
    want_d, want_i = ops.topk_merge_unique(d, cand, top_d, top_i)
    np.testing.assert_array_equal(np.asarray(got_i),
                                  np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_d),
                                  np.asarray(want_d))


def test_coop_pq_score_matches_adc_numpy_oracle(sources, queries_mod):
    """Semantic ground truth for the fused corner: per-lane ADC
    distances computed by plain numpy LUT gather-sum over the gathered
    codes, (d, position)-lex sorted — the selected ids must agree
    exactly and the distances to float tolerance."""
    src = sources["store_pq"]
    store_res = src.store.resident
    b = queries_mod.shape[0]
    k = src.track_width(4)
    leaf, ok = _window(store_res, b)
    g = src.gather(leaf, ok)
    ctx = src.query_ctx(queries_mod)
    top_d = jnp.full((b, k), jnp.inf)
    top_i = jnp.full((b, k), -1, jnp.int32)
    use_valid = refine.coop_mask(jnp.asarray(leaf, jnp.int32),
                                 jnp.asarray(ok), g.valid)
    got_d, got_i = src.score(ctx, g, use_valid, top_d, top_i,
                             share=True)
    codes = np.asarray(g.pool)[np.asarray(g.gather_idx).reshape(-1)]
    luts = np.asarray(ctx.luts)                      # [B, m, K]
    valid = np.asarray(use_valid).reshape(-1)
    pos = np.where(valid, np.asarray(g.row_idx).reshape(-1), -1)
    for lane in range(b):
        d = luts[lane][np.arange(codes.shape[1])[None, :],
                       codes].sum(1)
        d = np.where(valid, d, np.inf)
        order = np.lexsort((pos, d))
        finite = np.isfinite(d[order[:k]])
        np.testing.assert_array_equal(
            np.asarray(got_i[lane])[finite], pos[order[:k]][finite])
        np.testing.assert_allclose(
            np.asarray(got_d[lane])[finite], d[order[:k]][finite],
            rtol=1e-4, atol=1e-4)


def test_coop_pq_refine_step_never_materializes_full_matrix():
    """ISSUE 5 acceptance: with the fused kernel forced (interpret on
    CPU — the same lowering path CI exercises), the jitted coop-pq
    refine_step must not hold the [B, R] = [B, B*V*M] ADC distance
    matrix in any on-chip buffer: no f32[B, R] (nor a padded-lane
    variant) appears in the optimized HLO. The full-materialization
    oracle lowered over identical operands DOES contain it, so the
    assertion has teeth."""
    import functools

    from repro.kernels import ref as kref
    b, vm, m, K = 8, 96, 8, 16
    r = b * vm                                       # 768: distinctive
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.integers(0, K, size=(1024, m)), jnp.int32)
    gi = jnp.asarray(rng.integers(0, 1024, size=(b, vm)), jnp.int32)
    valid = jnp.asarray(np.ones((b, vm), bool))
    ctx = refine.ScoreCtx(
        qf=jnp.zeros((b, 4), jnp.float32), ids=jnp.arange(1024),
        norms=None,
        luts=jnp.asarray(rng.uniform(size=(b, m, K)), jnp.float32))
    top_d = jnp.full((b, 8), jnp.inf)
    top_i = jnp.full((b, 8), -1, jnp.int32)
    fused = jax.jit(functools.partial(refine.refine_step, share=True,
                                      pq=True, force_pallas=True))
    txt = fused.lower(ctx, pool, gi, gi, valid, top_d,
                      top_i).compile().as_text()
    padded_b = -(-b // 128) * 128  # ops pads lanes to the lane tile
    assert f"f32[{b},{r}]" not in txt
    assert f"f32[{padded_b},{r}]" not in txt

    cand = jnp.arange(r, dtype=jnp.int32)
    mat_txt = jax.jit(lambda c, l, i: kref.ref_pq_adc_select(
        c, l, i, 16)).lower(pool[gi.reshape(-1)], ctx.luts,
                            cand).compile().as_text()
    assert f"f32[{b},{r}]" in mat_txt


def test_pq_finalize_reports_exact_distances(sources, queries_mod):
    """PQSource.finalize re-ranks the pooled positions against raw
    exact.bin rows: reported distances equal brute-force distances to
    the returned ids."""
    src = sources["store_pq"]
    store = src.store
    b = queries_mod.shape[0]
    k = 4
    ctx = src.query_ctx(queries_mod)
    # hand it a synthetic pool of real padded positions
    rng = np.random.default_rng(1)
    ids_h = np.asarray(store.resident.ids)
    real = np.where(ids_h >= 0)[0]
    pool = rng.choice(real, size=(b, 3 * k), replace=False)
    top_i = jnp.asarray(pool, jnp.int32)
    top_d = jnp.zeros((b, 3 * k), jnp.float32)
    fd, fi, rbytes = src.finalize(ctx, top_d, top_i, k)
    assert rbytes > 0
    exact = np.asarray(store.exact_mmap, np.float32)
    qf = np.asarray(ctx.qf, np.float32)
    for lane in range(b):
        cand = exact[pool[lane]]
        d = ((cand - qf[lane]) ** 2).sum(1)
        cid = ids_h[pool[lane]]
        order = np.lexsort((cid, d))
        np.testing.assert_array_equal(np.asarray(fi[lane]),
                                      cid[order[:k]])
        np.testing.assert_allclose(np.asarray(fd[lane]), d[order[:k]],
                                   rtol=1e-5, atol=1e-6)


def test_identity_finalize_for_lossless_sources(sources, queries_mod):
    for name in ("resident", "store_f32", "store_bf16"):
        src = sources[name]
        ctx = src.query_ctx(queries_mod)
        td = jnp.zeros((2, 3))
        ti = jnp.zeros((2, 3), jnp.int32)
        fd, fi, extra = src.finalize(ctx, td, ti, 3)
        assert fd is td and fi is ti and extra == 0


def test_frontier_tick_advance_emit_stable_argsort_order():
    """Driving the shared tick/advance pair (exactly like the host
    loop) emits every lane's (lb, id)-stable argsort order, for any
    width/lookahead, including adversarial all-tied lbs."""
    rng = np.random.default_rng(2)
    b, L, v = 3, 37, 2
    lb = rng.choice([0.0, 1.0, 1.0, 2.5, 7.0], size=(b, L)) \
        .astype(np.float32)
    lb_sq = jnp.asarray(lb)
    want = np.argsort(lb, axis=1, kind="stable")
    for F in (5, 8, 64):
        F = min(F, L)
        fr = refine.frontier_init(b, F)
        active = jnp.ones((b,), bool)
        got = []
        for _ in range(0, L + v, v):
            fr, leaf = refine.frontier_tick(fr, lb_sq, active,
                                            v=v, lookahead=2 * v)
            got.append(np.asarray(leaf))
            fr, _ = refine.frontier_advance(fr, active, v=v)
        got = np.concatenate(got, axis=1)[:, :L]
        np.testing.assert_array_equal(got, want, err_msg=f"F={F}")


# --------------------------------------- engine over spilled shards
@pytest.mark.parametrize("codec", ["f32", "bf16", "pq"])
def test_engine_spilled_shard_serving_parity(codec, walk_data,
                                             queries_mod, tmp_path):
    """DistributedEngine.query over spill-built shards vs the resident
    shard_map path: bit-exact ids AND dists for lossless codecs across
    the guarantee taxonomy; pq passes the epsilon guarantee check
    after its exact re-rank."""
    mesh = jax.make_mesh((1,), ("data",))
    eng = DistributedEngine(mesh, method="dstree")
    kw = {"data_dtype": jnp.bfloat16} if codec == "bf16" else {}
    eng.build(walk_data,
              index=IndexSpec("dstree", leaf_cap=32, **kw),
              store=StoreSpec(spill_dir=str(tmp_path), codec=codec))
    k = 5
    guarantees = [Guarantee(epsilon=1.0),
                  Guarantee(delta=0.99, epsilon=0.5),
                  Guarantee(nprobe=4)]
    if codec != "pq":
        guarantees.insert(0, Guarantee())  # exact (pq warns: lossy)
    for g in guarantees:
        res = eng.query(queries_mod, k, g)
        ooc = eng.query(queries_mod, k, g, ooc=True)
        if codec == "pq":
            # lossy payload: held to the guarantee checks post re-rank
            # (the deterministic epsilon bound where one applies)
            assert bool(np.isfinite(np.asarray(ooc.dists)).all()), g
            assert bool((np.asarray(ooc.ids) >= 0).all()), g
            if g.delta == 1.0 and g.nprobe is None:
                bf = S.brute_force(queries_mod,
                                   jnp.asarray(walk_data), k)
                assert bool((np.asarray(ooc.dists)
                             <= (1 + g.epsilon)
                             * np.asarray(bf.dists) * (1 + 1e-4)
                             + 1e-4).all()), g
        else:
            np.testing.assert_array_equal(
                np.asarray(res.ids), np.asarray(ooc.ids), err_msg=str(g))
            np.testing.assert_array_equal(
                np.asarray(res.dists), np.asarray(ooc.dists),
                err_msg=str(g))
        assert ooc.stats["bytes_read"] > 0


def test_engine_open_spill_serves_without_resident(walk_data,
                                                   queries_mod,
                                                   tmp_path):
    """open_spill: an engine with NO resident index (and no mesh)
    auto-detects and serves the OOC path; per-shard caches stay warm
    across queries."""
    mesh = jax.make_mesh((1,), ("data",))
    built_eng = DistributedEngine(mesh, method="dstree")
    built_eng.build(
        walk_data,
        index=IndexSpec("dstree", leaf_cap=32,
                        data_dtype=jnp.bfloat16),
        store=StoreSpec(spill_dir=str(tmp_path), codec="bf16"))
    ref = built_eng.query(queries_mod, 5, Guarantee(epsilon=1.0))

    eng = DistributedEngine.open_spill(
        StoreSpec(spill_dir=str(tmp_path), keep_resident=False))
    assert eng.mesh is None and eng.stacked is None
    opts = {"cache_leaves": 10_000}  # hold every leaf: pure warm reuse
    got = eng.query(queries_mod, 5, Guarantee(epsilon=1.0),
                    ooc_opts=opts)
    np.testing.assert_array_equal(np.asarray(ref.ids),
                                  np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(ref.dists),
                                  np.asarray(got.dists))
    cold = got.stats["bytes_read"]
    got2 = eng.query(queries_mod, 5, Guarantee(epsilon=1.0),
                     ooc_opts=opts)
    np.testing.assert_array_equal(np.asarray(got.ids),
                                  np.asarray(got2.ids))
    warm = got2.stats["bytes_read"]
    assert cold > 0 and warm == 0  # caches stay warm across queries


def test_engine_ooc_cache_grows_with_batch(walk_data, tmp_path):
    """The serving front issues variable group sizes: a shard cache
    sized by the FIRST query's batch must be rebuilt, not crash with
    'cache thrash', when a larger batch arrives; the prefetcher thread
    persists with the cache across queries."""
    mesh = jax.make_mesh((1,), ("data",))
    eng = DistributedEngine(mesh, method="dstree")
    eng.build(walk_data, index=IndexSpec("dstree", leaf_cap=32),
              store=StoreSpec(spill_dir=str(tmp_path),
                              keep_resident=False))
    small = jnp.asarray(walk_data[:1])
    big = jnp.asarray(walk_data[:16] + 0.01)
    eng.query(small, 5, Guarantee(epsilon=1.0),
              ooc_opts={"cache_leaves": 1})
    (d,) = eng.shard_dirs
    pf_first = eng._shard_caches[d].prefetcher
    assert pf_first is not None
    eng.query(small, 5, Guarantee(epsilon=1.0),
              ooc_opts={"cache_leaves": 1})
    assert eng._shard_caches[d].prefetcher is pf_first  # persists
    res = eng.query(big, 5, Guarantee(epsilon=1.0), visit_batch=2,
                    ooc_opts={"cache_leaves": 1})  # must not thrash
    bf = S.brute_force(big, jnp.asarray(walk_data), 5)
    assert bool((np.asarray(res.dists[:, 0])
                 <= 2.0 * np.asarray(bf.dists[:, 0]) * (1 + 1e-4)
                 + 1e-4).all())
    # grown to the batch working set (clamped to the shard's leaves)
    store = eng._stores[d]
    assert eng._shard_caches[d].capacity >= min(32, store.num_leaves)
    assert eng._shard_caches[d].capacity > 1
    eng.close()
    assert not eng._shard_caches and pf_first._stop


def test_engine_build_keep_resident_false(walk_data, queries_mod,
                                          tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    eng = DistributedEngine(mesh, method="dstree")
    eng.build(walk_data, index=IndexSpec("dstree", leaf_cap=32),
              store=StoreSpec(spill_dir=str(tmp_path),
                              keep_resident=False))
    assert eng.stacked is None and eng.shard_dirs
    bf = S.brute_force(queries_mod, jnp.asarray(walk_data), 5)
    res = eng.query(queries_mod, 5, Guarantee())  # auto-OOC
    np.testing.assert_array_equal(np.asarray(res.ids),
                                  np.asarray(bf.ids))
    with pytest.raises(ValueError):
        DistributedEngine(mesh).build(
            walk_data, index=IndexSpec("dstree", leaf_cap=32),
            store=StoreSpec(keep_resident=False))
