"""Metric identities (paper §4.1), property-based."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import metrics as M

pytestmark = pytest.mark.tier1

SETTINGS = dict(max_examples=40, deadline=None)


def test_perfect_answers_score_one():
    ids = jnp.asarray([[3, 1, 2]])
    d = jnp.asarray([[1.0, 2.0, 3.0]])
    assert float(M.recall(ids, ids)[0]) == 1.0
    assert float(M.average_precision(ids, ids)[0]) == 1.0
    assert float(M.relative_error(d, d)[0]) == 0.0


def test_disjoint_answers_score_zero():
    got = jnp.asarray([[7, 8, 9]])
    true = jnp.asarray([[1, 2, 3]])
    assert float(M.recall(got, true)[0]) == 0.0
    assert float(M.average_precision(got, true)[0]) == 0.0


@given(st.lists(st.integers(0, 50), min_size=5, max_size=5, unique=True),
       st.lists(st.integers(0, 50), min_size=5, max_size=5, unique=True))
@settings(**SETTINGS)
def test_map_never_exceeds_recall(got, true):
    """AP weights correct items by precision <= 1, so MAP <= recall."""
    g = jnp.asarray([got])
    t = jnp.asarray([true])
    assert float(M.average_precision(g, t)[0]) <= \
        float(M.recall(g, t)[0]) + 1e-6


@given(st.integers(1, 5))
@settings(**SETTINGS)
def test_prefix_match_ap(k_hit):
    """First k_hit of 5 correct (in true order) -> AP = k_hit/5."""
    true = list(range(5))
    got = true[:k_hit] + [100 + i for i in range(5 - k_hit)]
    ap = float(M.average_precision(jnp.asarray([got]),
                                   jnp.asarray([true]))[0])
    np.testing.assert_allclose(ap, k_hit / 5, atol=1e-6)


def test_missing_ids_do_not_count():
    got = jnp.asarray([[-1, -1, 1]])
    true = jnp.asarray([[1, 2, 3]])
    assert float(M.recall(got, true)[0]) == np.float32(1 / 3)


def test_mre_guards_zero_distance():
    got = jnp.asarray([[0.0, 2.0]])
    true = jnp.asarray([[0.0, 1.0]])
    mre = float(M.relative_error(got, true)[0])
    assert np.isfinite(mre)
    np.testing.assert_allclose(mre, 1.0, atol=1e-5)


# ---------------------------------------------------- edge cases (PR 6)
def test_tied_distances_are_not_an_error():
    """Different ids at IDENTICAL distances: recall sees a wrong id,
    rank-paired MRE sees a perfect distance — both by design."""
    got_ids = jnp.asarray([[5, 6]])
    true_ids = jnp.asarray([[1, 2]])
    tied_d = jnp.asarray([[1.0, 1.0]])
    assert float(M.recall(got_ids, true_ids)[0]) == 0.0
    np.testing.assert_allclose(
        float(M.relative_error(tied_d, tied_d)[0]), 0.0, atol=1e-7)


def test_tie_swapped_order_scores_perfect():
    """Reordering within a distance tie must not cost recall or AP."""
    got = jnp.asarray([[2, 1, 3]])
    true = jnp.asarray([[1, 2, 3]])
    assert float(M.recall(got, true)[0]) == 1.0
    np.testing.assert_allclose(
        float(M.average_precision(got, true)[0]), 1.0, atol=1e-6)


def test_k_greater_than_collection():
    """k > n: both sides pad with -1 ids / inf distances (the ng
    incomplete-result shape). Pad slots match nothing and inf answer
    ranks are excluded from MRE — scores stay finite."""
    got_ids = jnp.asarray([[0, 1, -1]])
    true_ids = jnp.asarray([[0, 1, -1]])
    got_d = jnp.asarray([[1.0, 2.0, jnp.inf]])
    true_d = jnp.asarray([[1.0, 2.0, jnp.inf]])
    out = M.workload_metrics(got_ids, got_d, true_ids, true_d)
    np.testing.assert_allclose(out["avg_recall"], 2 / 3, atol=1e-6)
    np.testing.assert_allclose(out["map"], 2 / 3, atol=1e-6)
    assert np.isfinite(out["mre"])
    np.testing.assert_allclose(out["mre"], 0.0, atol=1e-7)


def test_empty_truth_scores_zero_not_nan():
    got_ids = jnp.zeros((2, 0), jnp.int32)
    got_d = jnp.zeros((2, 0), jnp.float32)
    out = M.workload_metrics(got_ids, got_d, got_ids, got_d)
    assert out["avg_recall"] == 0.0
    assert out["map"] == 0.0
    assert np.isfinite(out["mre"]) and out["mre"] == 0.0
    # a populated answer against an empty truth set is also 0, not nan
    got = jnp.asarray([[4, 5]])
    assert float(M.recall(got, jnp.zeros((1, 0), jnp.int32))[0]) == 0.0
    assert float(M.average_precision(
        got, jnp.zeros((1, 0), jnp.int32))[0]) == 0.0
