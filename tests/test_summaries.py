"""Property tests (hypothesis): the LOWER-BOUNDING INVARIANT.

Every guarantee in the paper rests on lb(Q, S) <= d(Q, S) for each
summarization. We verify it for PAA (iSAX), EAPCA (DSTree) and DFT
(VA+file) on arbitrary series, plus box-containment versions (distance
to any box containing summarize(S) lower-bounds d(Q, S))."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, hnp, settings, st

from repro.core.summaries import dft, eapca, paa, sax
from repro.kernels import ref

pytestmark = pytest.mark.tier1

SETTINGS = dict(max_examples=30, deadline=None)


def series_pair(n):
    return hnp.arrays(
        np.float32, (2, n),
        elements=st.floats(-50, 50, width=32,
                           allow_nan=False, allow_infinity=False),
    )


def true_dist_sq(q, s):
    d = q.astype(np.float64) - s.astype(np.float64)
    return float((d * d).sum())


@given(series_pair(64))
@settings(**SETTINGS)
def test_paa_lower_bounds(xs):
    q, s = xs
    l = 16
    pq = np.asarray(paa.transform(jnp.asarray(q), l))
    ps = np.asarray(paa.transform(jnp.asarray(s), l))
    w = 64 / l
    lb = w * ((pq - ps) ** 2).sum()
    assert lb <= true_dist_sq(q, s) * (1 + 1e-4) + 1e-3


@given(series_pair(64))
@settings(**SETTINGS)
def test_eapca_lower_bounds(xs):
    q, s = xs
    l = 8
    eq = np.asarray(eapca.transform(jnp.asarray(q[None]), l))[0]
    es = np.asarray(eapca.transform(jnp.asarray(s[None]), l))[0]
    w = 64 / l
    lb = w * ((eq - es) ** 2).sum()
    assert lb <= true_dist_sq(q, s) * (1 + 1e-4) + 1e-3


@given(series_pair(64), st.integers(2, 32))
@settings(**SETTINGS)
def test_dft_lower_bounds(xs, l):
    q, s = xs
    fq = np.asarray(dft.transform(jnp.asarray(q[None]), l))[0]
    fs = np.asarray(dft.transform(jnp.asarray(s[None]), l))[0]
    lb = ((fq - fs) ** 2).sum()
    assert lb <= true_dist_sq(q, s) * (1 + 1e-4) + 1e-3


@given(series_pair(64))
@settings(**SETTINGS)
def test_box_distance_lower_bounds_member_distance(xs):
    """If box contains summarize(S), boxdist(q) <= sumdist(q, s)."""
    q, s = xs
    l = 16
    pq = np.asarray(paa.transform(jnp.asarray(q), l))[None]
    ps = np.asarray(paa.transform(jnp.asarray(s), l))
    lo = (ps - np.abs(ps) * 0.1 - 0.01)[None]
    hi = (ps + np.abs(ps) * 0.1 + 0.01)[None]
    w = np.full(l, 64 / l, np.float32)
    boxd = float(np.asarray(ref.ref_box_mindist(
        jnp.asarray(pq), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(w)))[0, 0])
    sumd = float((64 / l) * ((pq[0] - ps) ** 2).sum())
    assert boxd <= sumd * (1 + 1e-4) + 1e-3


def test_dft_is_isometry_prefix():
    """Full-length DFT features preserve distances exactly (Parseval)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 64)).astype(np.float32)
    f = np.asarray(dft.transform(jnp.asarray(x), 64))
    d_time = ((x[0] - x[1]) ** 2).sum()
    d_freq = ((f[0] - f[1]) ** 2).sum()
    np.testing.assert_allclose(d_time, d_freq, rtol=1e-4)


def test_sax_breakpoints_are_normal_quantiles():
    b = sax.breakpoints(4)
    assert len(b) == 3
    np.testing.assert_allclose(b[1], 0.0, atol=1e-6)
    assert b[0] < 0 < b[2]
    b8 = sax.breakpoints(8)
    assert np.all(np.diff(b8) > 0)


def test_sax_encode_respects_breakpoints():
    x = jnp.asarray(np.linspace(-3, 3, 64, dtype=np.float32)[None])
    codes = np.asarray(sax.encode(x, 16, 8))
    assert codes.min() >= 0 and codes.max() <= 7
    assert np.all(np.diff(codes[0]) >= 0)  # increasing series -> symbols


def test_eapca_uses_population_std():
    """The bound needs ddof=0; ddof=1 would break lower-bounding."""
    x = np.asarray([[1.0, 2.0, 3.0, 4.0]], np.float32)
    e = np.asarray(eapca.transform(jnp.asarray(x), 1))[0]
    np.testing.assert_allclose(e[0], 2.5, atol=1e-6)
    np.testing.assert_allclose(e[1], np.std([1, 2, 3, 4]), atol=1e-6)
