"""Shared fixtures. NOTE: no XLA_FLAGS here by design — unit tests and
benches must see the real single CPU device; multi-device tests spawn
subprocesses with their own flags (see tests/test_distributed.py)."""

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier1: fast core subset (scripts/verify.sh runs it first)")
    config.addinivalue_line(
        "markers",
        "slow: multi-minute model/distributed smoke tests")


@pytest.fixture(scope="session")
def walk_data():
    """Z-normalized random-walk collection [512, 128] (paper's Rand)."""
    rng = np.random.default_rng(0)
    x = np.cumsum(rng.normal(size=(512, 128)), axis=1).astype(np.float32)
    x = (x - x.mean(1, keepdims=True)) / (x.std(1, keepdims=True) + 1e-9)
    return x


@pytest.fixture(scope="session")
def walk_queries(walk_data):
    rng = np.random.default_rng(1)
    idx = rng.choice(walk_data.shape[0], 6, replace=False)
    return (walk_data[idx]
            + 0.1 * rng.normal(size=(6, walk_data.shape[1]))
            ).astype(np.float32)
