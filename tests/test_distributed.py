"""Multi-device tests (subprocess with forced host device count — the
main test process must keep seeing 1 device, per the dry-run contract).
Covers: distributed engine correctness, multi-pod-shaped lower+compile
for a reduced arch, roofline collective accounting, compressed psum.

Every test here spawns an 8-device subprocess (minutes each), so the
whole module is slow-marked: excluded from the tier1/verify-fast
subset, run by verify-full (the merge gate) and re-run by the nightly
CI job (docs/CI.md)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_engine_matches_brute_force_across_shards():
    stdout = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import search as S
        from repro.core.engine import DistributedEngine
        from repro.core.guarantees import Guarantee
        from repro.core import IndexSpec, StoreSpec
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        data = np.cumsum(rng.normal(size=(2048, 64)), axis=1)
        data = ((data - data.mean(1, keepdims=True))
                / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
        Q = jnp.asarray(data[rng.choice(2048, 4)]
                        + 0.05 * rng.normal(size=(4, 64)).astype(np.float32))
        bf = S.brute_force(Q, jnp.asarray(data), 5)
        eng = DistributedEngine(mesh, axes=("data",), method="dstree")
        eng.build(data, index=IndexSpec("dstree", leaf_cap=32))
        res = eng.query(Q, 5, Guarantee())
        ids_ok = bool((jnp.sort(res.ids, 1) == jnp.sort(bf.ids, 1)).all())
        d_ok = bool(jnp.allclose(res.dists, bf.dists, rtol=1e-2, atol=1e-2))
        eps = eng.query(Q, 5, Guarantee(epsilon=1.0))
        eps_ok = bool((eps.dists <= 2.0 * bf.dists * 1.001 + 1e-3).all())
        print("RESULT", ids_ok, d_ok, eps_ok)
    """)
    assert "RESULT True True True" in stdout


def test_engine_spilled_shards_parity_multishard():
    """Out-of-core serving over 4 spilled shards is bit-exact vs the
    resident shard_map path (ids AND dists) across guarantees, and
    open_spill serves the same answers with no resident index at all."""
    stdout = run_sub("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from repro.core.engine import DistributedEngine
        from repro.core.guarantees import Guarantee
        from repro.core import IndexSpec, StoreSpec
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        data = np.cumsum(rng.normal(size=(2048, 64)), axis=1)
        data = ((data - data.mean(1, keepdims=True))
                / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
        Q = jnp.asarray(data[rng.choice(2048, 4)]
                        + 0.05 * rng.normal(size=(4, 64)).astype(np.float32))
        ok = True
        with tempfile.TemporaryDirectory() as tmp:
            eng = DistributedEngine(mesh, axes=("data",), method="dstree")
            eng.build(data, index=IndexSpec("dstree", leaf_cap=32),
                      store=StoreSpec(spill_dir=tmp, codec="f32"))
            assert len(eng.shard_dirs) == 4
            for g in (Guarantee(), Guarantee(epsilon=1.0),
                      Guarantee(delta=0.99, epsilon=0.5),
                      Guarantee(nprobe=4)):
                res = eng.query(Q, 5, g)
                ooc = eng.query(Q, 5, g, ooc=True)
                ok &= bool((res.ids == ooc.ids).all())
                ok &= bool((res.dists == ooc.dists).all())
            opened = DistributedEngine.open_spill(
                StoreSpec(spill_dir=tmp, keep_resident=False))
            o = opened.query(Q, 5, Guarantee(epsilon=1.0))
            r = eng.query(Q, 5, Guarantee(epsilon=1.0))
            ok &= bool((o.ids == r.ids).all())
            ok &= bool((o.dists == r.dists).all())
        print("RESULT", ok)
    """, timeout=900)
    assert "RESULT True" in stdout


def test_multipod_engine_axes():
    stdout = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import search as S
        from repro.core.engine import DistributedEngine
        from repro.core.guarantees import Guarantee
        from repro.core import IndexSpec
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        data = rng.normal(size=(1024, 64)).astype(np.float32)
        Q = jnp.asarray(data[:3] + 0.01)
        bf = S.brute_force(Q, jnp.asarray(data), 4)
        eng = DistributedEngine(mesh, axes=("pod", "data"),
                                method="isax2+")
        eng.build(data, index=IndexSpec("isax2+", leaf_cap=32))
        res = eng.query(Q, 4, Guarantee())
        print("RESULT",
              bool((jnp.sort(res.ids,1) == jnp.sort(bf.ids,1)).all()))
    """)
    assert "RESULT True" in stdout


def test_reduced_dryrun_cell_compiles_multipod():
    """The dry-run machinery end-to-end on a (2,2,2) pod mesh with a
    reduced config — proves the 'pod' axis shards and the roofline
    report assembles. The full 512-device run is benchmarks territory."""
    stdout = run_sub("""
        import dataclasses, jax
        from repro.launch.dryrun import lower_cell
        from repro.configs import get_smoke_config
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        import repro.launch.dryrun as dr
        import repro.configs as C
        # patch get_config to the smoke config for speed
        smoke = C.get_smoke_config("jamba-v0.1-52b")
        dr.get_config = lambda a: smoke
        with mesh:
            rep = lower_cell("jamba-v0.1-52b", "train_4k", mesh,
                             grad_accum=2,
                             arch_overrides={"attn_dense_threshold": 8192})
        print("STATUS", rep["status"], rep["bottleneck"],
              rep["n_collectives"] > 0)
    """, devices=8, timeout=900)
    assert "STATUS ok" in stdout
    assert "True" in stdout


def test_decode_cell_compiles():
    stdout = run_sub("""
        import jax
        import repro.launch.dryrun as dr
        import repro.configs as C
        smoke = C.get_smoke_config("gemma2-2b")
        dr.get_config = lambda a: smoke
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh:
            rep = dr.lower_cell("gemma2-2b", "decode_32k", mesh)
        print("STATUS", rep["status"])
    """, devices=8, timeout=900)
    assert "STATUS ok" in stdout


def test_compressed_psum_wire_semantics():
    stdout = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.train.compress import compressed_psum
        mesh = jax.make_mesh((4,), ("pod",))
        x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0
        def f(xs):
            return compressed_psum(xs, "pod")
        y = shard_map(f, mesh=mesh, in_specs=P("pod"),
                      out_specs=P("pod"))(x)
        true = x.sum(axis=0, keepdims=True)
        err = float(jnp.abs(y[:1] - true).max())
        rel = err / float(jnp.abs(true).max())
        print("REL", rel < 0.02)
    """, devices=4)
    assert "REL True" in stdout


def test_roofline_parser_on_real_hlo():
    stdout = run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.roofline import parse_collectives
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        def f(x, w):
            return (x @ w).sum()
        xs = jax.ShapeDtypeStruct((64, 32), jnp.bfloat16,
            sharding=NamedSharding(mesh, P("data", None)))
        ws = jax.ShapeDtypeStruct((32, 16), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(None, "model")))
        c = jax.jit(f).lower(xs, ws).compile()
        ops = parse_collectives(c.as_text(), 8)
        kinds = {o.op for o in ops}
        sane = all(o.wire_bytes >= 0 and o.group_size >= 1 for o in ops)
        print("PARSE", len(ops) > 0, sane, "all-reduce" in kinds)
    """)
    assert "PARSE True True True" in stdout
