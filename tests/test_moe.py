"""MoE routing invariants: weight conservation, capacity drops, shared
experts, identity-expert sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models.params import initialize

KEY = jax.random.PRNGKey(0)


def test_routing_weights_renormalized():
    cfg = moe.MoEConfig(num_experts=8, top_k=2, d_ff_expert=16)
    logits = jax.random.normal(KEY, (32, 8))
    w, idx, aux = moe._route(logits, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(axis=1)), 1.0, atol=1e-5)
    assert int(idx.max()) < 8


def test_capacity_drop_fraction_reported():
    cfg = moe.MoEConfig(num_experts=4, top_k=1, d_ff_expert=8,
                        capacity_factor=0.5)
    params = initialize(moe.moe_specs(16, cfg, jnp.float32), KEY)
    x = jax.random.normal(KEY, (2, 32, 16))
    out, aux = moe.moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert float(aux["moe_dropped_frac"]) > 0.0  # cf=0.5 must drop


def test_no_drops_at_high_capacity():
    cfg = moe.MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                        capacity_factor=4.0)
    params = initialize(moe.moe_specs(16, cfg, jnp.float32), KEY)
    x = jax.random.normal(KEY, (2, 16, 16))
    out, aux = moe.moe_apply(params, x, cfg)
    assert float(aux["moe_dropped_frac"]) == 0.0


def test_aux_loss_uniform_router_is_one():
    """Switch aux loss equals 1 exactly under perfectly uniform load."""
    cfg = moe.MoEConfig(num_experts=4, top_k=1, d_ff_expert=8)
    t = 4000
    logits = jnp.zeros((t, 4)) + jax.random.normal(KEY, (t, 4)) * 1e-4
    _, _, aux = moe._route(logits, cfg)
    np.testing.assert_allclose(float(aux["moe_aux_loss"]), 1.0, atol=0.05)


def test_shared_experts_contribute():
    cfg = moe.MoEConfig(num_experts=4, top_k=1, d_ff_expert=8,
                        num_shared=2, capacity_factor=2.0)
    params = initialize(moe.moe_specs(16, cfg, jnp.float32), KEY)
    x = jax.random.normal(KEY, (1, 8, 16))
    out, _ = moe.moe_apply(params, x, cfg)
    zeroed = jax.tree_util.tree_map(jnp.zeros_like, params["shared"])
    params2 = dict(params)
    params2["shared"] = zeroed
    out2, _ = moe.moe_apply(params2, x, cfg)
    assert float(jnp.abs(out - out2).max()) > 1e-6


def test_dispatch_gather_roundtrip_identity_experts():
    """With experts = identity-ish (wi zeroed, wo zeroed) output is 0 —
    i.e. routing machinery itself adds nothing spurious."""
    cfg = moe.MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                        capacity_factor=4.0)
    params = initialize(moe.moe_specs(16, cfg, jnp.float32), KEY)
    params = dict(params)
    params["wo"] = jnp.zeros_like(params["wo"])
    x = jax.random.normal(KEY, (1, 8, 16))
    out, _ = moe.moe_apply(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
