"""Out-of-core storage tier (repro.store): round-trip bit-exactness and
search parity. The load-bearing claim is that search_ooc is the SAME
algorithm as the in-memory search — identical visit order and stopping
predicates, only residency differs — so every assertion here is exact
equality, not tolerance. Lossy codecs (format v2) keep that bar where
it is keepable: bf16 ooc is bit-exact vs in-memory search over the
bfloat16 index; pq is held to the paper's guarantee checks after the
exact re-rank."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IndexSpec, StoreSpec
from repro.core import guarantees as G
from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree, isax, vafile
from repro.store import (DeviceLeafCache, LeafPrefetcher, LeafStore,
                         StoreFormatDeprecationWarning)

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def walk_data_mod(walk_data):
    return walk_data


@pytest.fixture(scope="module")
def queries_mod(walk_queries):
    return jnp.asarray(walk_queries)


@pytest.fixture(scope="module")
def built(walk_data_mod):
    return dstree.build(walk_data_mod, leaf_cap=32)


def assert_same(ref, got):
    np.testing.assert_array_equal(np.asarray(ref.ids),
                                  np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(ref.dists),
                                  np.asarray(got.dists))
    np.testing.assert_array_equal(np.asarray(ref.leaves_visited),
                                  np.asarray(got.leaves_visited))
    np.testing.assert_array_equal(np.asarray(ref.rows_scanned),
                                  np.asarray(got.rows_scanned))


def test_save_load_round_trip_bit_exact(built, tmp_path):
    d = built.save(str(tmp_path / "idx"))
    full = FrozenIndex.load(d)
    for fld in ("box_lo", "box_hi", "weights", "offsets", "data", "ids"):
        np.testing.assert_array_equal(
            np.asarray(getattr(built, fld)),
            np.asarray(getattr(full, fld)), err_msg=fld)
    np.testing.assert_array_equal(np.asarray(built.hist.edges),
                                  np.asarray(full.hist.edges))
    for fld in ("kind", "summary", "n_summary", "max_leaf", "n_total",
                "series_len"):
        assert getattr(built, fld) == getattr(full, fld), fld


def test_bf16_payload_round_trip(walk_data_mod, tmp_path):
    ix = dstree.build(walk_data_mod, leaf_cap=32,
                      data_dtype=jnp.bfloat16)
    d = ix.save(str(tmp_path / "bf16"))
    full = FrozenIndex.load(d)
    assert full.data.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(ix.data),
                                  np.asarray(full.data))


def test_summaries_load_keeps_raw_data_off_device(built, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    assert isinstance(store, LeafStore)
    assert store.resident.data.shape[0] == 0         # placeholder only
    assert isinstance(store.mmap, np.memmap)
    assert store.mmap.shape[0] == np.asarray(built.data).shape[0]


@pytest.mark.parametrize(
    "delta,epsilon,nprobe",
    [(1.0, 0.0, None),      # exact
     (1.0, 1.0, None),      # epsilon-approximate
     (0.99, 1.0, None),     # delta-epsilon
     (1.0, 0.0, 4)])        # ng(nprobe)
def test_ooc_matches_in_memory_small_cache(built, queries_mod, tmp_path,
                                           delta, epsilon, nprobe):
    """Cache (6 leaves) far smaller than the working set (16 leaves)."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    g = G.Guarantee(delta=delta, epsilon=epsilon, nprobe=nprobe)
    ref = S.search(built, queries_mod, 5, g)
    ooc = S.search_ooc(store, queries_mod, 5, g, cache_leaves=6)
    assert_same(ref, ooc.result)
    assert ooc.stats["bytes_read"] > 0
    assert ooc.stats["misses"] > 0


def test_ooc_matches_for_vafile_visit_batch(walk_data_mod, queries_mod,
                                            tmp_path):
    """VA+file: a 'leaf' is a single series, visit_batch=64 per hop."""
    va = vafile.build(walk_data_mod)
    store = FrozenIndex.load(va.save(str(tmp_path / "va")),
                             resident="summaries")
    ref = S.search(va, queries_mod, 5, G.epsilon(1.0), visit_batch=64)
    ooc = S.search_ooc(store, queries_mod, 5, G.epsilon(1.0),
                       visit_batch=64, cache_leaves=400)
    assert_same(ref, ooc.result)


def test_ooc_matches_for_isax(walk_data_mod, queries_mod, tmp_path):
    ix = isax.build(walk_data_mod, leaf_cap=32)
    store = FrozenIndex.load(ix.save(str(tmp_path / "isax")),
                             resident="summaries")
    ref = S.search(ix, queries_mod, 5)
    ooc = S.search_ooc(store, queries_mod, 5,
                       cache_leaves=max(ix.num_leaves // 4, 6))
    assert_same(ref, ooc.result)


def test_warm_cache_hits_and_fewer_reads(built, queries_mod, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=store.num_leaves)
    cold = S.search_ooc(store, queries_mod, 5, cache=cache)
    cache.reset_counters()
    warm = S.search_ooc(store, queries_mod, 5, cache=cache)
    assert_same(cold.result, warm.result)
    assert warm.stats["bytes_read"] == 0       # fully cache-resident
    assert warm.stats["hit_rate"] == 1.0
    assert cold.stats["bytes_read"] > 0


def test_cache_eviction_counters_and_capacity(built, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=4)
    L = store.num_leaves
    cache.get_slots(list(range(4)))
    assert cache.misses == 4 and cache.hits == 0
    cache.get_slots([0, 1])                    # resident -> hits
    assert cache.hits == 2
    for lf in range(4, L):                     # forces eviction
        cache.get_slots([lf])
    assert cache.misses == L
    assert cache.slots.shape[0] == 4           # pool never grows
    assert len(cache.slot_of) <= 4
    # evicted leaves must re-read
    before = cache.bytes_read
    cache.get_slots([0])
    assert cache.bytes_read > before


def test_prefetcher_stages_and_takes(built, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    with LeafPrefetcher(store) as pf:
        pf.schedule([0, 1, 2])
        import time
        deadline = time.time() + 5.0
        got = None
        while got is None and time.time() < deadline:
            got = pf.take(1)
            if got is None:
                time.sleep(0.01)
        assert got is not None
        np.testing.assert_array_equal(got, store.read_leaf(1))
        assert pf.take(1) is None              # popped exactly once


# ---------------------------------------------------------------- v2 codecs


@pytest.fixture(scope="module")
def pq_store_dir(built, tmp_path_factory):
    d = tmp_path_factory.mktemp("pq_store")
    return built.save(str(d / "pq"), codec="pq")


@pytest.mark.parametrize("codec", ["f32", "bf16"])
@pytest.mark.parametrize("share", [False, True])
@pytest.mark.parametrize("delta,epsilon", [(1.0, 0.0), (0.99, 1.0)])
def test_ooc_codec_parity_bit_exact(built, queries_mod, tmp_path,
                                    codec, share, delta, epsilon):
    """f32/bf16 ooc == in-memory search over the decoded index, bit
    exact, for both the per-lane and the cooperative scoring path."""
    d = built.save(str(tmp_path / codec), codec=codec)
    full = FrozenIndex.load(d)
    if codec == "bf16":
        assert full.data.dtype == jnp.bfloat16
    store = FrozenIndex.load(d, resident="summaries")
    g = G.Guarantee(delta=delta, epsilon=epsilon)
    ref = S.search(full, queries_mod, 5, g, share_gathers=share)
    ooc = S.search_ooc(store, queries_mod, 5, g,
                       share_gathers=share, cache_leaves=6)
    assert_same(ref, ooc.result)
    assert ooc.stats["codec"] == codec
    assert ooc.stats["share_gathers"] is share


@pytest.mark.parametrize("share", [False, True])
@pytest.mark.parametrize("delta,epsilon", [(1.0, 1.0), (0.99, 1.0)])
def test_ooc_pq_guarantee_with_exact_rerank(
        walk_data_mod, queries_mod, pq_store_dir, share, delta,
        epsilon):
    """pq + exact re-rank must satisfy the epsilon / delta-epsilon
    guarantee checks (Definition 5) against brute force — the reported
    distances are EXACT for the returned neighbors, so the (1+eps)
    bound is checkable directly."""
    store = FrozenIndex.load(pq_store_dir, resident="summaries")
    assert store.codec == "pq" and store.codebook is not None
    bf = S.brute_force(queries_mod, jnp.asarray(walk_data_mod), 5)
    ooc = S.search_ooc(store, queries_mod, 5,
                       G.Guarantee(delta=delta, epsilon=epsilon),
                       share_gathers=share, cache_leaves=6)
    ok = (np.asarray(ooc.result.dists)
          <= (1 + epsilon) * np.asarray(bf.dists) * (1 + 1e-4) + 1e-4)
    if delta == 1.0:
        assert ok.all()
    else:
        assert ok.mean() >= 0.9
    assert ooc.stats["bytes_read_rerank"] > 0


def test_pq_exact_guarantee_request_warns(queries_mod, pq_store_dir):
    """epsilon=0 (exact) cannot be honored over the lossy pq payload —
    the ADC kth-best can prune the true neighbor's leaf early — so
    asking for it must warn (nprobe / epsilon>0 requests must not)."""
    store = FrozenIndex.load(pq_store_dir, resident="summaries")
    with pytest.warns(UserWarning, match="cannot honor the exact"):
        S.search_ooc(store, queries_mod, 5, cache_leaves=6)
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error", UserWarning)
        S.search_ooc(store, queries_mod, 5, G.epsilon(1.0),
                     cache_leaves=6)
        S.search_ooc(store, queries_mod, 5, G.ng(4), cache_leaves=6)


def test_dataset_nbytes_is_codec_invariant(built, tmp_path,
                                           pq_store_dir):
    """stats['dataset_bytes'] must mean the RAW collection for every
    codec, not the encoded payload, or %-data metrics skew 2x/64x."""
    raw = np.asarray(built.data).nbytes
    for codec in ("f32", "bf16"):
        d = built.save(str(tmp_path / f"dn_{codec}"), codec=codec)
        store = FrozenIndex.load(d, resident="summaries")
        assert store.dataset_nbytes == raw, codec
    store = FrozenIndex.load(pq_store_dir, resident="summaries")
    assert store.dataset_nbytes == raw


def test_pq_resident_full_round_trip_bit_exact(built, pq_store_dir):
    """codec="pq" keeps exact.bin, so resident="full" reconstitutes the
    original index bit-exactly despite the lossy refinement payload."""
    full = FrozenIndex.load(pq_store_dir)
    np.testing.assert_array_equal(np.asarray(built.data),
                                  np.asarray(full.data))
    np.testing.assert_array_equal(np.asarray(built.ids),
                                  np.asarray(full.ids))


def test_codec_payload_sizes_and_bytes_read(built, queries_mod,
                                            tmp_path, pq_store_dir):
    """The bytes-read currency: bf16 payload is exactly half of f32,
    pq codes far smaller still, and search_ooc bytes_read shrinks
    accordingly (the ISSUE's ~2x / ~8-16x targets at this scale)."""
    reads = {}
    payload = {}
    for codec in ("f32", "bf16", "pq"):
        d = pq_store_dir if codec == "pq" else \
            built.save(str(tmp_path / codec), codec=codec)
        payload[codec] = os.path.getsize(os.path.join(d, "data.bin"))
        store = FrozenIndex.load(d, resident="summaries")
        ooc = S.search_ooc(store, queries_mod, 5, G.epsilon(1.0),
                           cache_leaves=6)
        reads[codec] = ooc.stats["bytes_read"]
    assert payload["bf16"] * 2 == payload["f32"]
    assert payload["pq"] * 8 <= payload["f32"]
    assert reads["bf16"] <= 0.55 * reads["f32"]
    assert reads["pq"] <= 0.5 * reads["f32"]


def test_share_gathers_never_reads_more(built, queries_mod, tmp_path):
    """Cooperative scoring only tightens each lane's bsf, so it can
    only stop earlier — bytes_read must not grow."""
    d = built.save(str(tmp_path / "coop"))
    store = FrozenIndex.load(d, resident="summaries")
    solo = S.search_ooc(store, queries_mod, 5, G.epsilon(1.0),
                        cache_leaves=6, prefetch=False)
    coop = S.search_ooc(store, queries_mod, 5, G.epsilon(1.0),
                        cache_leaves=6, prefetch=False,
                        share_gathers=True)
    assert coop.stats["bytes_read"] <= solo.stats["bytes_read"]


def test_share_gathers_returns_distinct_ids(built, queries_mod,
                                            tmp_path):
    """Regression: a leaf pooled at two iterations is scored twice for
    every lane; without the dedup merge the top-k collapses to
    duplicate ids AND the kth-best shrinks below the true kth distinct
    distance (pruning too early). Both cooperative paths must return
    k distinct neighbors."""
    d = built.save(str(tmp_path / "dedup"))
    store = FrozenIndex.load(d, resident="summaries")
    ooc = S.search_ooc(store, queries_mod, 5, G.epsilon(1.0),
                       cache_leaves=6, share_gathers=True)
    ref = S.search(built, queries_mod, 5, G.epsilon(1.0),
                   share_gathers=True)
    for ids in (np.asarray(ooc.result.ids), np.asarray(ref.ids)):
        for row in ids:
            real = row[row >= 0]
            assert len(np.unique(real)) == len(real), row


def test_prefetch_false_disables_attached_prefetcher(
        built, queries_mod, tmp_path):
    """Regression: prefetch=False must suppress speculative scheduling
    even when the caller-supplied cache has a prefetcher attached —
    the flag exists to measure pure demand-path reads."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    pf = LeafPrefetcher(store)
    cache = DeviceLeafCache(store, capacity_leaves=6, prefetcher=pf)
    try:
        out = S.search_ooc(store, queries_mod, 5, cache=cache,
                           prefetch=False)
        assert out.stats["prefetch_bytes_read"] == 0
        assert pf.leaves_read == 0
        assert out.stats["bytes_read"] == out.stats["bytes_read_sync"]
    finally:
        pf.close()


def test_scatter_fill_traces_are_bucketed(built, tmp_path):
    """Miss batches pad to the next power of two, so the jitted scatter
    compiles O(log capacity) variants, not one per miss count."""
    from repro.store.cache import _scatter_fill
    if not hasattr(_scatter_fill, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=16)
    L = store.num_leaves                       # 16 for this fixture
    cache.get_slots(list(range(5)))            # 5 misses -> pad 8
    before = _scatter_fill._cache_size()
    cache.get_slots(list(range(5, 11)))        # 6 misses -> pad 8
    cache.get_slots(list(range(11, min(L, 18))))  # 5-7 misses -> pad 8
    assert _scatter_fill._cache_size() == before


def test_pq_rerank_distance_is_exact_at_zero(walk_data_mod, tmp_path):
    """The re-rank uses the direct difference form: a query identical
    to a stored series must come back at distance exactly 0.0 (the
    expanded |q|^2-2qx+|x|^2 form loses ~1e-3 to cancellation here)."""
    ix = dstree.build(walk_data_mod, leaf_cap=32)
    d = ix.save(str(tmp_path / "pq0"), codec="pq")
    store = FrozenIndex.load(d, resident="summaries")
    q = jnp.asarray(walk_data_mod[:4])         # exact stored rows
    ooc = S.search_ooc(store, q, 5, G.epsilon(1.0))
    ids = np.asarray(ooc.result.ids)
    dists = np.asarray(ooc.result.dists)
    for lane in range(4):
        hit = np.where(ids[lane] == lane)[0]
        assert hit.size == 1, (lane, ids[lane])
        assert dists[lane, hit[0]] == 0.0


def test_engine_spill_codec_threads_through(walk_data_mod, tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    eng = DistributedEngine(mesh, method="dstree")
    eng.build(walk_data_mod, index=IndexSpec("dstree", leaf_cap=32),
              store=StoreSpec(spill_dir=str(tmp_path), codec="bf16"))
    store = FrozenIndex.load(eng.shard_dirs[0], resident="summaries")
    assert store.codec == "bf16"
    assert store.mmap.dtype == jnp.bfloat16


def test_v1_store_reads_with_deprecation_warning(built, tmp_path):
    """v1 read-compat: a pre-codec artifact loads as codec="f32" but
    warns (scripts/verify.sh escalates the warning to an error so the
    repo itself never regenerates v1 stores)."""
    d = built.save(str(tmp_path / "v1"))
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["format_version"] = 1
    for key in ("codec", "payload_dtype", "payload_cols"):
        meta.pop(key, None)
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.warns(StoreFormatDeprecationWarning):
        store = FrozenIndex.load(d, resident="summaries")
    assert store.codec == "f32"
    assert store.payload_cols == built.series_len


def test_newer_format_version_is_an_explicit_error(built, tmp_path):
    d = built.save(str(tmp_path / "vfuture"))
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["format_version"] = 99
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="newer"):
        FrozenIndex.load(d)


# ------------------------------------------------------- satellite bugfixes


def test_fill_reuses_device_pool_buffer(built, tmp_path):
    """Regression: the _fill scatter must donate the slot pool so the
    device buffer is updated in place (O(misses) per iteration), not
    copied wholesale (O(capacity * max_leaf * n))."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=8)
    cache.get_slots([0, 1])          # compile+donate path for 2 misses
    ptr = cache.slots.unsafe_buffer_pointer()
    cache.get_slots([2, 3])
    assert cache.slots.unsafe_buffer_pointer() == ptr
    cache.get_slots([4])             # different miss count: new trace
    cache.get_slots([5])
    assert cache.slots.unsafe_buffer_pointer() == ptr


def test_prefetcher_reset_counters_quiesces(built, tmp_path):
    """Regression: a cold-pass read still in flight must not land its
    bytes AFTER reset_counters zeroes them (the straggler race that
    polluted warm-run stats in bench_query_disk)."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    with LeafPrefetcher(store) as pf:
        pf.schedule(list(range(store.num_leaves)))
        pf.reset_counters()          # drops the queue, waits in-flight
        t0 = time.monotonic()
        while time.monotonic() - t0 < 0.3:
            assert pf.bytes_read == 0 and pf.leaves_read == 0
            time.sleep(0.02)
        # counters still work for reads scheduled AFTER the reset
        pf.schedule([0])
        deadline = time.monotonic() + 5.0
        while pf.take(0, timeout=0.1) is None \
                and time.monotonic() < deadline:
            pass
        assert pf.bytes_read == store.leaf_nbytes(0)
        assert pf.leaves_read == 1


def test_per_request_hit_counting_with_duplicates(built, tmp_path):
    """Pin the get_slots accounting semantics: every occurrence served
    without a disk read is a hit; misses count distinct reads; the
    distinct view is reported alongside."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=8)
    # 4 lanes share leaf 0, 2 request leaf 1: two reads, four dup hits
    slots = cache.get_slots([0, 0, 1, 0, 0, 1])
    assert cache.misses == 2
    assert cache.hits == 4            # per-request: dups are hits
    assert cache.hits_distinct == 0   # nothing resident at batch start
    assert slots[0] == slots[1] == slots[3] == slots[4]
    # resident leaves: every occurrence is a hit, one distinct each
    cache.get_slots([0, 1, 0])
    assert cache.hits == 7 and cache.hits_distinct == 2
    st = cache.stats()
    assert st["hit_rate"] == pytest.approx(7 / 9)
    assert st["hit_rate_distinct"] == pytest.approx(2 / 4)


def test_warm_cache_with_attached_prefetcher_stats(built, queries_mod,
                                                   tmp_path):
    """Caller-supplied cache with its OWN prefetcher: the stats fold-in
    must route through cache.bytes_read (no double count), and a warm
    pass — after the quiescing reset — reads nothing."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    pf = LeafPrefetcher(store)
    cache = DeviceLeafCache(store, capacity_leaves=store.num_leaves,
                            prefetcher=pf)
    try:
        cold = S.search_ooc(store, queries_mod, 5, cache=cache)
        assert cache.prefetcher is pf       # not detached
        assert cold.stats["bytes_read"] == \
            cold.stats["bytes_read_sync"] \
            + cold.stats["prefetch_bytes_read"]
        cache.reset_counters()
        warm = S.search_ooc(store, queries_mod, 5, cache=cache)
        assert_same(cold.result, warm.result)
        assert warm.stats["bytes_read"] == 0
        assert warm.stats["prefetch_bytes_read"] == 0
        assert warm.stats["hit_rate"] == 1.0
    finally:
        pf.close()


def test_read_leaf_out_reuse_zeroes_tail(built, tmp_path):
    """A reused out= buffer must not leak rows from a larger leaf that
    previously occupied it."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    sizes = store.offsets_h[1:] - store.offsets_h[:-1]
    big = int(np.argmax(sizes))
    small = int(np.argmin(np.where(sizes > 0, sizes, sizes.max())))
    buf = store.read_leaf(big)
    buf[:] = 7                       # poison: simulate stale rows
    out = store.read_leaf(small, out=buf)
    assert out is buf
    ssz = store.leaf_size(small)
    np.testing.assert_array_equal(
        out[:ssz], store.mmap[store.offsets_h[small]:
                              store.offsets_h[small] + ssz])
    assert not np.any(out[ssz:])     # tail fully zeroed


def test_engine_spill_round_trip(walk_data_mod, queries_mod, tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    eng = DistributedEngine(mesh, method="dstree")
    eng.build(walk_data_mod, index=IndexSpec("dstree", leaf_cap=32),
              store=StoreSpec(spill_dir=str(tmp_path)))
    assert eng.shard_dirs is not None and len(eng.shard_dirs) == 1
    store = FrozenIndex.load(eng.shard_dirs[0], resident="summaries")
    assert store.meta["n_total"] == walk_data_mod.shape[0]
    ref = S.brute_force(queries_mod, jnp.asarray(walk_data_mod), 5)
    ooc = S.search_ooc(store, queries_mod, 5, cache_leaves=6)
    np.testing.assert_array_equal(np.asarray(ref.ids),
                                  np.asarray(ooc.result.ids))
    # brute_force uses the fused l2 kernel; tolerance covers the f32
    # summation-order difference vs the refinement einsum
    np.testing.assert_allclose(np.asarray(ref.dists),
                               np.asarray(ooc.result.dists),
                               rtol=1e-4, atol=1e-4)
