"""Out-of-core storage tier (repro.store): round-trip bit-exactness and
search parity. The load-bearing claim is that search_ooc is the SAME
algorithm as the in-memory search — identical visit order and stopping
predicates, only residency differs — so every assertion here is exact
equality, not tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree, isax, vafile
from repro.store import DeviceLeafCache, LeafPrefetcher, LeafStore

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def walk_data_mod(walk_data):
    return walk_data


@pytest.fixture(scope="module")
def queries_mod(walk_queries):
    return jnp.asarray(walk_queries)


@pytest.fixture(scope="module")
def built(walk_data_mod):
    return dstree.build(walk_data_mod, leaf_cap=32)


def assert_same(ref, got):
    np.testing.assert_array_equal(np.asarray(ref.ids),
                                  np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(ref.dists),
                                  np.asarray(got.dists))
    np.testing.assert_array_equal(np.asarray(ref.leaves_visited),
                                  np.asarray(got.leaves_visited))
    np.testing.assert_array_equal(np.asarray(ref.rows_scanned),
                                  np.asarray(got.rows_scanned))


def test_save_load_round_trip_bit_exact(built, tmp_path):
    d = built.save(str(tmp_path / "idx"))
    full = FrozenIndex.load(d)
    for fld in ("box_lo", "box_hi", "weights", "offsets", "data", "ids"):
        np.testing.assert_array_equal(
            np.asarray(getattr(built, fld)),
            np.asarray(getattr(full, fld)), err_msg=fld)
    np.testing.assert_array_equal(np.asarray(built.hist.edges),
                                  np.asarray(full.hist.edges))
    for fld in ("kind", "summary", "n_summary", "max_leaf", "n_total",
                "series_len"):
        assert getattr(built, fld) == getattr(full, fld), fld


def test_bf16_payload_round_trip(walk_data_mod, tmp_path):
    ix = dstree.build(walk_data_mod, leaf_cap=32,
                      data_dtype=jnp.bfloat16)
    d = ix.save(str(tmp_path / "bf16"))
    full = FrozenIndex.load(d)
    assert full.data.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(ix.data),
                                  np.asarray(full.data))


def test_summaries_load_keeps_raw_data_off_device(built, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    assert isinstance(store, LeafStore)
    assert store.resident.data.shape[0] == 0         # placeholder only
    assert isinstance(store.mmap, np.memmap)
    assert store.mmap.shape[0] == np.asarray(built.data).shape[0]


@pytest.mark.parametrize(
    "delta,epsilon,nprobe",
    [(1.0, 0.0, None),      # exact
     (1.0, 1.0, None),      # epsilon-approximate
     (0.99, 1.0, None),     # delta-epsilon
     (1.0, 0.0, 4)])        # ng(nprobe)
def test_ooc_matches_in_memory_small_cache(built, queries_mod, tmp_path,
                                           delta, epsilon, nprobe):
    """Cache (6 leaves) far smaller than the working set (16 leaves)."""
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    ref = S.search(built, queries_mod, 5, delta=delta, epsilon=epsilon,
                   nprobe=nprobe)
    ooc = S.search_ooc(store, queries_mod, 5, delta=delta,
                       epsilon=epsilon, nprobe=nprobe, cache_leaves=6)
    assert_same(ref, ooc.result)
    assert ooc.stats["bytes_read"] > 0
    assert ooc.stats["misses"] > 0


def test_ooc_matches_for_vafile_visit_batch(walk_data_mod, queries_mod,
                                            tmp_path):
    """VA+file: a 'leaf' is a single series, visit_batch=64 per hop."""
    va = vafile.build(walk_data_mod)
    store = FrozenIndex.load(va.save(str(tmp_path / "va")),
                             resident="summaries")
    ref = S.search(va, queries_mod, 5, epsilon=1.0, visit_batch=64)
    ooc = S.search_ooc(store, queries_mod, 5, epsilon=1.0,
                       visit_batch=64, cache_leaves=400)
    assert_same(ref, ooc.result)


def test_ooc_matches_for_isax(walk_data_mod, queries_mod, tmp_path):
    ix = isax.build(walk_data_mod, leaf_cap=32)
    store = FrozenIndex.load(ix.save(str(tmp_path / "isax")),
                             resident="summaries")
    ref = S.search(ix, queries_mod, 5)
    ooc = S.search_ooc(store, queries_mod, 5,
                       cache_leaves=max(ix.num_leaves // 4, 6))
    assert_same(ref, ooc.result)


def test_warm_cache_hits_and_fewer_reads(built, queries_mod, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=store.num_leaves)
    cold = S.search_ooc(store, queries_mod, 5, cache=cache)
    cache.reset_counters()
    warm = S.search_ooc(store, queries_mod, 5, cache=cache)
    assert_same(cold.result, warm.result)
    assert warm.stats["bytes_read"] == 0       # fully cache-resident
    assert warm.stats["hit_rate"] == 1.0
    assert cold.stats["bytes_read"] > 0


def test_cache_eviction_counters_and_capacity(built, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    cache = DeviceLeafCache(store, capacity_leaves=4)
    L = store.num_leaves
    cache.get_slots(list(range(4)))
    assert cache.misses == 4 and cache.hits == 0
    cache.get_slots([0, 1])                    # resident -> hits
    assert cache.hits == 2
    for lf in range(4, L):                     # forces eviction
        cache.get_slots([lf])
    assert cache.misses == L
    assert cache.slots.shape[0] == 4           # pool never grows
    assert len(cache.slot_of) <= 4
    # evicted leaves must re-read
    before = cache.bytes_read
    cache.get_slots([0])
    assert cache.bytes_read > before


def test_prefetcher_stages_and_takes(built, tmp_path):
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    with LeafPrefetcher(store) as pf:
        pf.schedule([0, 1, 2])
        import time
        deadline = time.time() + 5.0
        got = None
        while got is None and time.time() < deadline:
            got = pf.take(1)
            if got is None:
                time.sleep(0.01)
        assert got is not None
        np.testing.assert_array_equal(got, store.read_leaf(1))
        assert pf.take(1) is None              # popped exactly once


def test_engine_spill_round_trip(walk_data_mod, queries_mod, tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    eng = DistributedEngine(mesh, method="dstree")
    eng.build(walk_data_mod, leaf_cap=32, spill_dir=str(tmp_path))
    assert eng.shard_dirs is not None and len(eng.shard_dirs) == 1
    store = FrozenIndex.load(eng.shard_dirs[0], resident="summaries")
    assert store.meta["n_total"] == walk_data_mod.shape[0]
    ref = S.brute_force(queries_mod, jnp.asarray(walk_data_mod), 5)
    ooc = S.search_ooc(store, queries_mod, 5, cache_leaves=6)
    np.testing.assert_array_equal(np.asarray(ref.ids),
                                  np.asarray(ooc.result.ids))
    # brute_force uses the fused l2 kernel; tolerance covers the f32
    # summation-order difference vs the refinement einsum
    np.testing.assert_allclose(np.asarray(ref.dists),
                               np.asarray(ooc.result.dists),
                               rtol=1e-4, atol=1e-4)
