"""QALSH behavior + index artifact persistence (checkpoint roundtrip,
bf16 data variant)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search as S
from repro.core.indexes import dstree, qalsh
from repro.core.metrics import workload_metrics
from repro.train.checkpoint import Checkpointer


@pytest.fixture(scope="module")
def bf(walk_data, walk_queries):
    return S.brute_force(jnp.asarray(walk_queries),
                         jnp.asarray(walk_data), 5)


def test_qalsh_recall_grows_with_budget(walk_data, walk_queries, bf):
    idx = qalsh.build(walk_data, m=8)
    lo = qalsh.query(idx, jnp.asarray(walk_queries), 5, steps=1,
                     frontier=16)
    hi = qalsh.query(idx, jnp.asarray(walk_queries), 5, steps=6,
                     frontier=64)
    mlo = workload_metrics(lo.ids, lo.dists, bf.ids, bf.dists)
    mhi = workload_metrics(hi.ids, hi.dists, bf.ids, bf.dists)
    assert mhi["avg_recall"] >= mlo["avg_recall"]
    assert mhi["avg_recall"] > 0.6
    assert int(hi.rows_scanned.sum()) >= int(lo.rows_scanned.sum())


def test_qalsh_refines_on_raw_distances(walk_data, walk_queries, bf):
    """QALSH re-ranks candidates on true distances: recall == MAP
    (paper C5 applies to it, unlike IMI)."""
    idx = qalsh.build(walk_data, m=8)
    res = qalsh.query(idx, jnp.asarray(walk_queries), 5, steps=6,
                      frontier=64)
    m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
    assert abs(m["avg_recall"] - m["map"]) < 1e-6


def test_frozen_index_checkpoint_roundtrip(tmp_path, walk_data,
                                           walk_queries, bf):
    """The searchable artifact persists/restores through the same
    checkpointer as model state (fault-tolerance for the search half)."""
    idx = dstree.build(walk_data, leaf_cap=32)
    ck = Checkpointer(str(tmp_path))
    arrays = {
        "box_lo": idx.box_lo, "box_hi": idx.box_hi,
        "weights": idx.weights, "offsets": idx.offsets,
        "data": idx.data, "ids": idx.ids,
        "hist_edges": idx.hist.edges, "hist_cdf": idx.hist.cdf,
    }
    ck.save(1, {"index": arrays}, sync=True)
    _, state, _ = ck.restore({"index": arrays})
    from repro.core.histogram import DistanceHistogram

    r = state["index"]
    idx2 = dataclasses.replace(
        idx, box_lo=r["box_lo"], box_hi=r["box_hi"],
        weights=r["weights"], offsets=r["offsets"], data=r["data"],
        ids=r["ids"],
        hist=DistanceHistogram(r["hist_edges"], r["hist_cdf"]))
    a = S.search(idx, jnp.asarray(walk_queries), 5)
    b = S.search(idx2, jnp.asarray(walk_queries), 5)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_allclose(a.dists, b.dists, atol=0)


def test_bf16_data_index_keeps_exact_ranking(walk_data, walk_queries,
                                             bf):
    """§Perf C1: bf16 refinement stream — MAP impact measured."""
    idx = dstree.build(walk_data, leaf_cap=32, data_dtype=jnp.bfloat16)
    assert idx.data.dtype == jnp.bfloat16
    res = S.search(idx, jnp.asarray(walk_queries), 5)
    m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
    assert m["avg_recall"] >= 0.95  # bf16 rounding may perturb ties
    assert m["mre"] < 0.01
