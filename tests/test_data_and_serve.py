"""Data pipeline statelessness + serving path tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import pipeline, randomwalk, tokens
from repro.models import model as M
from repro.models.params import initialize
from repro.serve.batching import (Request, Scheduler, bucket_of,
                                  guarantee_for_deadline,
                                  retrieval_groups)
from repro.serve.serve_step import generate

KEY = jax.random.PRNGKey(0)


def test_randomwalk_stateless_addressing():
    a = randomwalk.generate(0, 8, 32)
    b = randomwalk.generate(0, 4, 32, start=4)
    np.testing.assert_array_equal(a[4:], b)
    c = randomwalk.generate(1, 8, 32)
    assert np.abs(a - c).max() > 0


def test_tokens_deterministic_and_sliceable():
    a = tokens.batch_at_step(0, 5, 8, 16, 100)
    b = tokens.batch_at_step(0, 5, 8, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = tokens.batch_at_step(0, 6, 8, 16, 100)
    assert np.abs(np.asarray(a["tokens"]) - np.asarray(c["tokens"])).max() > 0
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))


def test_prefetcher_orders_steps():
    seen = []

    def mk(step):
        return {"step": step}

    pf = pipeline.Prefetcher(mk, start_step=3, prefetch=2)
    for _ in range(4):
        s, b = next(pf)
        seen.append(s)
    pf.close()
    assert seen == [3, 4, 5, 6]


def test_generate_produces_tokens():
    cfg = get_smoke_config("gemma2-2b")
    params = initialize(M.model_specs(cfg), KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    toks, _ = generate(params, cfg, prompt, 5)
    assert toks.shape == (2, 5)
    assert int(toks.max()) < cfg.vocab_size


def test_generate_encdec():
    cfg = get_smoke_config("seamless-m4t-medium")
    params = initialize(M.model_specs(cfg), KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    frames = jax.random.normal(KEY, (2, cfg.encoder_frames, cfg.d_model),
                               cfg.compute_dtype)
    toks, _ = generate(params, cfg, prompt, 4, frames=frames)
    assert toks.shape == (2, 4)


def test_scheduler_buckets_and_padding():
    s = Scheduler(max_batch=2, min_bucket=8)
    for uid, ln in [(0, 5), (1, 7), (2, 20), (3, 6)]:
        s.submit(Request(uid=uid, prompt=np.arange(ln, dtype=np.int32)))
    bucket, reqs = s.next_batch()
    assert bucket == 8 and [r.uid for r in reqs] == [0, 1]
    padded = s.pad_prompts(bucket, reqs)
    assert padded.shape == (2, 8)
    assert padded[0, :3].sum() == 0  # left-padded
    # oldest-head-first across buckets: uid 2 (bucket 32) was
    # submitted before uid 3 (bucket 8), so the big bucket drains
    # next — under the old smallest-bucket-first policy sustained
    # small-prompt load starved it forever
    bucket2, reqs2 = s.next_batch()
    assert bucket2 == 32 and [r.uid for r in reqs2] == [2]
    bucket3, reqs3 = s.next_batch()
    assert bucket3 == 8 and [r.uid for r in reqs3] == [3]


def test_deadline_maps_to_guarantee():
    """The full taxonomy ladder: relaxed -> epsilon, moderate ->
    delta-epsilon (probabilistic), tight -> ng(nprobe) with nprobe
    shrinking as the budget does."""
    g = guarantee_for_deadline(None)
    assert g.kind in ("epsilon", "exact")
    assert guarantee_for_deadline(60.0, full_budget_ms=50.0).kind \
        == g.kind
    mid = guarantee_for_deadline(40.0, full_budget_ms=50.0)
    assert mid.kind == "delta-epsilon" and mid.delta < 1.0
    tight = guarantee_for_deadline(12.0, full_budget_ms=50.0)
    assert tight.kind == "ng" and tight.nprobe >= 1
    tighter = guarantee_for_deadline(2.0, full_budget_ms=50.0)
    assert tighter.kind == "ng" and tighter.nprobe <= tight.nprobe


def test_retrieval_groups_mixed_deadlines():
    """A drained batch with mixed deadlines partitions into one group
    per mapped guarantee, order-deterministic, every request placed
    exactly once."""
    reqs = [Request(uid=u, prompt=np.arange(4, dtype=np.int32),
                    deadline_ms=dl, series=np.zeros(8, np.float32))
            for u, dl in enumerate([None, 40.0, 2.0, 60.0, 40.0, 2.0])]
    groups = retrieval_groups(reqs, full_budget_ms=50.0, epsilon=0.1)
    kinds = [g.kind for g, _ in groups]
    assert kinds == ["epsilon", "delta-epsilon", "ng"]
    placed = sorted(r.uid for _, rs in groups for r in rs)
    assert placed == list(range(6))
    by_kind = {g.kind: sorted(r.uid for r in rs) for g, rs in groups}
    assert by_kind["epsilon"] == [0, 3]
    assert by_kind["delta-epsilon"] == [1, 4]
    assert by_kind["ng"] == [2, 5]
    # identical deadlines must land in the SAME group (hashable
    # Guarantee), not fragment into duplicates
    assert len(groups) == 3


def test_run_retrieval_mixed_batch_drives_engine_per_group():
    """Scheduler.run_retrieval: one engine.query per guarantee group,
    padded to a pow-2 lane bucket, results scattered back per uid."""
    from repro.core.search import SearchResult

    calls = []

    class FakeEngine:
        def query(self, q, k, g):
            calls.append((int(q.shape[0]), g))
            b = q.shape[0]
            return SearchResult(
                dists=jnp.zeros((b, k), jnp.float32),
                ids=jnp.tile(jnp.arange(k, dtype=jnp.int32), (b, 1)),
                leaves_visited=jnp.zeros((b,), jnp.int32),
                rows_scanned=jnp.zeros((b,), jnp.int32),
                lb_computed=jnp.int32(0),
            )

    reqs = [Request(uid=u, prompt=np.arange(4, dtype=np.int32),
                    deadline_ms=dl, series=np.full(8, u, np.float32))
            for u, dl in enumerate([None, 2.0, 40.0, None, None])]
    # one request opts out of retrieval entirely
    reqs.append(Request(uid=9, prompt=np.arange(4, dtype=np.int32)))
    out = Scheduler().run_retrieval(FakeEngine(), reqs, k=3,
                                    full_budget_ms=50.0, epsilon=0.1)
    assert sorted(out) == [0, 1, 2, 3, 4]        # uid 9 skipped
    assert len(calls) == 3                        # one per group
    # epsilon group has 3 requests -> padded to 4 lanes
    sizes = {g.kind: b for b, g in calls}
    assert sizes["epsilon"] == 4 and sizes["ng"] == 1
    assert sizes["delta-epsilon"] == 1
    assert out[1]["kind"] == "ng" and out[2]["kind"] == "delta-epsilon"
    assert out[0]["ids"].shape == (3,)


def test_bucket_of_powers():
    assert bucket_of(1) == 16
    assert bucket_of(16) == 16
    assert bucket_of(17) == 32
