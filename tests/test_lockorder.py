"""obs.lockorder: the dynamic half of the concurrency gate.

The static guarded-by rule (tests/test_analysis.py) proves each field
is touched under its lock; these tests prove the locks themselves are
taken in a consistent ORDER. Synthetic cases pin the recorder's
semantics (inversion detection, re-entrancy, cross-thread cycle
composition); the real-harness case wraps the actual prefetcher and
cache locks and asserts the documented order
``cache._lock -> prefetch._lock`` (store/cache.py) is what concurrent
traffic observes, and that the graph is acyclic.
"""

import threading

import pytest

from repro import obs
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree
from repro.store import DeviceLeafCache, LeafPrefetcher

pytestmark = pytest.mark.tier1


def test_inversion_detected_and_reported():
    rec = obs.LockOrderRecorder()
    a = rec.wrap(threading.Lock(), "A")
    b = rec.wrap(threading.Lock(), "B")
    with a:
        with b:
            pass
    rec.assert_acyclic()            # A->B alone is a fine order
    with b:
        with a:
            pass
    with pytest.raises(obs.LockOrderError) as ei:
        rec.assert_acyclic()
    # the report names the cycle even though THIS run never deadlocked
    assert "A" in str(ei.value) and "B" in str(ei.value)


def test_consistent_order_and_rlock_reentry_are_clean():
    rec = obs.LockOrderRecorder()
    a = rec.wrap(threading.RLock(), "A")
    b = rec.wrap(threading.Lock(), "B")
    for _ in range(3):
        with a:
            with a:                 # re-entrant hold: no self-edge
                with b:
                    pass
    assert rec.edges() == {"A": {"B"}}
    rec.assert_acyclic()


def test_cycle_composed_across_threads():
    """A->B, B->C, C->A observed by THREE different threads: no single
    thread ever saw an inversion, but the composed graph is a deadlock
    waiting for the right interleaving — exactly what per-thread
    reasoning misses."""
    rec = obs.LockOrderRecorder()
    locks = {n: rec.wrap(threading.Lock(), n) for n in "ABC"}

    def hold_pair(x, y):
        with locks[x]:
            with locks[y]:
                pass

    for pair in [("A", "B"), ("B", "C"), ("C", "A")]:
        t = threading.Thread(target=hold_pair, args=pair)
        t.start()
        t.join()
    cyc = rec.find_cycle()
    assert cyc is not None and cyc[0] == cyc[-1]
    with pytest.raises(obs.LockOrderError):
        rec.assert_acyclic()


def test_condition_interface_survives_wrapping():
    """Prefetcher's lock is a Condition — wait/notify must pass
    through the proxy untouched."""
    rec = obs.LockOrderRecorder()
    cond = rec.wrap(threading.Condition(), "cond")
    ready = []

    def waiter():
        with cond:
            while not ready:
                cond.wait(timeout=5.0)

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        ready.append(1)
        cond.notify_all()
    t.join(timeout=5.0)
    assert not t.is_alive()
    rec.assert_acyclic()


def test_cache_prefetcher_lock_order_is_acyclic(walk_data, tmp_path):
    """The real pair: DeviceLeafCache holds its lock across _fill,
    which calls LeafPrefetcher.take — so the documented order is
    cache._lock -> prefetch._lock. Concurrent get_slots traffic (cold
    misses + CLOCK churn at capacity 4) must observe exactly that edge
    direction and nothing cyclic."""
    built = dstree.build(walk_data, leaf_cap=32)
    store = FrozenIndex.load(built.save(str(tmp_path / "idx")),
                             resident="summaries")
    rec = obs.LockOrderRecorder()
    with LeafPrefetcher(store) as pf:
        cache = DeviceLeafCache(store, capacity_leaves=4,
                                prefetcher=pf)
        # swap in tracked proxies post-construction: the proxies wrap
        # the SAME underlying lock objects, so the prefetcher's reader
        # thread (already parked on the raw Condition) stays coherent
        pf._lock = obs.wrap_lock(pf._lock, "prefetch._lock", rec)
        cache._lock = obs.wrap_lock(cache._lock, "cache._lock", rec)

        n = store.num_leaves
        pf.schedule(range(min(n, 8)))

        def traffic(seed):
            for i in range(12):
                lo = (seed + i) % n
                cache.get_slots([lo, (lo + 1) % n, lo])

        threads = [threading.Thread(target=traffic, args=(s,))
                   for s in (0, 3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    edges = rec.edges()
    assert "prefetch._lock" in edges.get("cache._lock", set()), edges
    # the reverse edge would be the inversion we built the recorder
    # to catch
    assert "cache._lock" not in edges.get("prefetch._lock", set())
    rec.assert_acyclic()
