"""The typed build/query surface (core/spec.py): spec semantics, the
one-release deprecation shims, and the mixing errors.

The shims are load-bearing API: external callers on the old kwarg
spellings must get the SAME behavior plus an APIDeprecationWarning
(an error under scripts/verify.sh, so in-repo callers can't regress),
and a caller mixing the two spellings must get a TypeError, not a
silent precedence guess.
"""

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IndexSpec, StoreSpec
from repro.core import guarantees as G
from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.core.indexes import dstree
from repro.core.spec import APIDeprecationWarning

pytestmark = pytest.mark.tier1


def _data(n=128, length=32, seed=0):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.normal(size=(n, length)), axis=1)
    return ((x - x.mean(1, keepdims=True))
            / (x.std(1, keepdims=True) + 1e-9)).astype(np.float32)


# ----------------------------------------------------------- the specs
def test_index_spec_is_frozen_hashable_and_merges_params():
    a = IndexSpec("dstree", {"leaf_cap": 32}, paa_segments=8)
    assert a.build_params == {"leaf_cap": 32, "paa_segments": 8}
    # sorted-item-tuple storage: kwarg order can't change identity
    b = IndexSpec("dstree", paa_segments=8, leaf_cap=32)
    assert a == b and hash(a) == hash(b)
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.method = "isax2+"


@pytest.mark.parametrize("bad, msg", [
    (dict(replicas=0), "replicas"),
    (dict(replicas=2), "spill_dir"),          # replicas w/o spill
    (dict(keep_resident=False), "spill_dir"),  # ooc w/o spill
    (dict(spill_dir="/tmp/x", delta_max_rows=0), "delta_max_rows"),
])
def test_store_spec_validate_rejects(bad, msg):
    with pytest.raises(ValueError, match=msg):
        StoreSpec(**bad).validate()


def test_store_spec_validate_accepts_defaults():
    assert StoreSpec().validate() == StoreSpec()


# ------------------------------------------------- build()/open_spill
def test_legacy_build_kwargs_warn_and_match_spec_build(tmp_path):
    data = _data()
    with pytest.warns(APIDeprecationWarning, match="IndexSpec"):
        old = DistributedEngine(mesh=None, shards=2).build(
            data, leaf_cap=16, spill_dir=str(tmp_path / "a"),
            codec="f32", keep_resident=False)
    new = DistributedEngine(mesh=None, shards=2).build(
        data, index=IndexSpec("dstree", leaf_cap=16),
        store=StoreSpec(spill_dir=str(tmp_path / "b"), codec="f32",
                        keep_resident=False))
    q = jnp.asarray(data[:4])
    ro, rn = old.query(q, 5, G.exact()), new.query(q, 5, G.exact())
    assert np.array_equal(np.asarray(ro.ids), np.asarray(rn.ids))
    assert np.array_equal(np.asarray(ro.dists), np.asarray(rn.dists))
    old.close()
    new.close()


def test_build_mixing_spec_and_loose_is_a_type_error(tmp_path):
    data = _data()
    eng = DistributedEngine(mesh=None, shards=2)
    with pytest.raises(TypeError, match="IndexSpec"):
        eng.build(data, index=IndexSpec("dstree"), leaf_cap=16)
    with pytest.raises(TypeError, match="StoreSpec"):
        eng.build(data, store=StoreSpec(spill_dir=str(tmp_path)),
                  spill_dir=str(tmp_path))


def test_open_spill_bare_string_is_deprecated(tmp_path):
    data = _data()
    eng = DistributedEngine(mesh=None, shards=2).build(
        data, index=IndexSpec("dstree", leaf_cap=16),
        store=StoreSpec(spill_dir=str(tmp_path), codec="f32",
                        keep_resident=False))
    eng.close()
    with pytest.warns(APIDeprecationWarning, match="StoreSpec"):
        old = DistributedEngine.open_spill(str(tmp_path))
    new = DistributedEngine.open_spill(
        StoreSpec(spill_dir=str(tmp_path), keep_resident=False))
    q = jnp.asarray(data[:4])
    ro, rn = old.query(q, 5, G.exact()), new.query(q, 5, G.exact())
    assert np.array_equal(np.asarray(ro.ids), np.asarray(rn.ids))
    assert np.array_equal(np.asarray(ro.dists), np.asarray(rn.dists))
    old.close()
    new.close()


def test_open_spill_spec_requires_spill_dir():
    with pytest.raises(ValueError, match="spill_dir"):
        DistributedEngine.open_spill(StoreSpec())


# ------------------------------------------------ guarantee spelling
def test_loose_guarantee_kwargs_warn_and_match_object_spelling():
    data = _data()
    idx = dstree.build(data, leaf_cap=16)
    q = jnp.asarray(data[:4])
    with pytest.warns(APIDeprecationWarning, match="Guarantee"):
        old = S.search(idx, q, 5, delta=0.99, epsilon=1.0)
    new = S.search(idx, q, 5, G.delta_epsilon(0.99, 1.0))
    assert np.array_equal(np.asarray(old.ids), np.asarray(new.ids))
    assert np.array_equal(np.asarray(old.dists),
                          np.asarray(new.dists))


def test_guarantee_object_plus_loose_kwargs_is_a_type_error():
    data = _data()
    idx = dstree.build(data, leaf_cap=16)
    q = jnp.asarray(data[:4])
    with pytest.raises(TypeError, match="Guarantee"):
        S.search(idx, q, 5, G.exact(), epsilon=1.0)


def test_no_guarantee_defaults_to_exact():
    data = _data()
    idx = dstree.build(data, leaf_cap=16)
    q = jnp.asarray(data[:4])
    with warnings.catch_warnings():
        warnings.simplefilter("error", APIDeprecationWarning)
        dflt = S.search(idx, q, 5)
    ex = S.search(idx, q, 5, G.exact())
    assert np.array_equal(np.asarray(dflt.ids), np.asarray(ex.ids))
    assert np.array_equal(np.asarray(dflt.dists),
                          np.asarray(ex.dists))
