"""End-to-end behaviour tests: the paper's full pipeline on one box —
generate data -> build indexes -> answer queries across the guarantee
taxonomy -> evaluate with the paper's measures -> reproduce headline
findings at reduced scale."""

import jax.numpy as jnp
import pytest

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.histogram import build_histogram, f_of, r_delta
from repro.core.indexes import dstree, isax
from repro.core.metrics import workload_metrics
from repro.data import queries as queries_mod
from repro.data import randomwalk

import jax


@pytest.fixture(scope="module")
def world():
    data = randomwalk.generate(3, 1024, 128)
    q = queries_mod.noisy_queries(data, 8)
    bf = S.brute_force(jnp.asarray(q), jnp.asarray(data), 10)
    return data, q, bf


def test_full_pipeline_exact_answers(world):
    data, q, bf = world
    for build, vb in [(isax.build, 1), (dstree.build, 1)]:
        idx = build(data, leaf_cap=64)
        res = S.search(idx, jnp.asarray(q), 10, visit_batch=vb)
        m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
        assert m["map"] == pytest.approx(1.0)
        assert m["avg_recall"] == pytest.approx(1.0)


def test_paper_c2_epsilon_buys_throughput_keeps_accuracy(world):
    """Fig 8a-c: growing epsilon slashes work; accuracy stays ~1 for
    small epsilon and empirical MRE << epsilon."""
    data, q, bf = world
    idx = dstree.build(data, leaf_cap=64)
    work, maps, mres = [], [], []
    for eps in (0.0, 0.5, 1.0, 2.0, 5.0):
        r = S.search(idx, jnp.asarray(q), 10, G.epsilon(eps))
        m = workload_metrics(r.ids, r.dists, bf.ids, bf.dists)
        work.append(int(r.rows_scanned.sum()))
        maps.append(m["map"])
        mres.append(m["mre"])
    assert work == sorted(work, reverse=True)
    assert work[-1] < work[0]
    assert maps[1] > 0.9  # eps=0.5 still near-exact
    for eps, mre in zip((0.5, 1.0, 2.0, 5.0), mres[1:]):
        assert mre <= eps + 1e-6  # guarantee
        assert mre < 0.5 * eps + 0.05  # empirically far below (C2)


def test_paper_c3_delta_stop_is_weak(world):
    """Fig 8d-e: the histogram-estimated r_delta rarely triggers — the
    negative result the paper reports."""
    data, q, bf = world
    idx = dstree.build(data, leaf_cap=64)
    ex = S.search(idx, jnp.asarray(q), 10)
    de = S.search(idx, jnp.asarray(q), 10, G.Guarantee(delta=0.99))
    # delta=0.99 may prune a little but stays within 2x of exact work,
    # and accuracy stays high
    m = workload_metrics(de.ids, de.dists, bf.ids, bf.dists)
    assert m["avg_recall"] > 0.8
    assert int(de.leaves_visited.sum()) <= int(ex.leaves_visited.sum())


def test_histogram_calibration(world):
    data, q, bf = world
    hist = build_histogram(data, jax.random.PRNGKey(0), n_pairs=20000)
    # F is a CDF
    assert float(f_of(hist, jnp.float32(0.0))) == pytest.approx(0.0,
                                                                abs=1e-3)
    big = float(hist.edges[-1])
    assert float(f_of(hist, jnp.float32(big))) == pytest.approx(1.0,
                                                                abs=1e-3)
    # r_delta shrinks as delta -> 1 and as N grows
    r9 = float(r_delta(hist, 0.9, 1024))
    r99 = float(r_delta(hist, 0.99, 1024))
    assert r99 <= r9
    rbig = float(r_delta(hist, 0.9, 10**9))
    assert rbig <= r9
    assert float(r_delta(hist, 1.0, 1024)) == 0.0


def test_ng_first_leaf_is_decent(world):
    """The paper's baseline observation: the very first bsf (one leaf)
    is already a usable answer (it's why ng-approximate works)."""
    data, q, bf = world
    idx = dstree.build(data, leaf_cap=64)
    r = S.search(idx, jnp.asarray(q), 10, G.ng(1))
    m = workload_metrics(r.ids, r.dists, bf.ids, bf.dists)
    assert m["avg_recall"] > 0.3
    assert m["mre"] < 0.5
