"""PR 9 continuous-batching front: admission, lanes, re-entrancy.

Four layers:

  admission  AdmissionController units — depth cap + reject reason,
             the serve.queue_depth gauge, accept/reject/shed
             counters, hysteresis latching, the degrade_tier ladder.
  scheduler  the starvation regression (oldest-head drain order under
             sustained small-bucket load) and the remaining-budget
             guarantee remap (a request that burned its budget in the
             queue drains at the tier its remaining time affords).
  front      ServeFront semantics over a stub engine — routing,
             rejection, shedding, stop(drain=...), error isolation.
  stress     N submitter threads against lane workers over a REAL
             spilled multi-shard engine: every answer bit-exact (ids
             AND dists) vs the serial oracle, no dropped or
             duplicated uids, and the dynamic lock graph
             (front cond + engine per-copy locks + cache/prefetcher
             locks) stays acyclic — the engine re-entrancy contract
             the tentpole introduced.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import search as S
from repro.core import IndexSpec, StoreSpec
from repro.core.engine import DistributedEngine, QueryResult
from repro.core.guarantees import Guarantee
from repro.serve.admission import AdmissionController, degrade_tier
from repro.serve.batching import (Request, Scheduler,
                                  guarantee_for_deadline,
                                  remaining_budget_ms, retrieval_groups)
from repro.serve.loop import LANES, Rejected, ServeFront, lane_of

pytestmark = pytest.mark.tier1

N, DIM, SHARDS, K = 512, 32, 4, 5


# ------------------------------------------------------------ admission
def test_admission_cap_rejects_with_reason():
    a = AdmissionController(max_depth=3)
    c_acc = obs.REGISTRY.counter("serve.admission.accepted",
                                 kind="epsilon")
    c_rej = obs.REGISTRY.counter("serve.admission.rejected",
                                 reason="queue_full")
    c_acc.mark()
    c_rej.mark()
    assert [a.try_admit("epsilon") for _ in range(3)] == [None] * 3
    assert a.depth == 3
    assert a.try_admit("epsilon") == "queue_full"
    assert a.depth == 3
    assert c_acc.since_mark == 3 and c_rej.since_mark == 1
    a.release(2)
    assert a.depth == 1 and a.try_admit("epsilon") is None


def test_admission_gauge_tracks_depth():
    a = AdmissionController(max_depth=8)
    g = obs.REGISTRY.gauge("serve.queue_depth")
    a.try_admit()
    a.try_admit()
    assert g.value == 2
    a.release()
    assert g.value == 1
    a.release(5)  # clamps at zero, never negative
    assert g.value == 0 and a.depth == 0


def test_admission_shedding_hysteresis():
    a = AdmissionController(max_depth=8, shed_high_frac=0.75,
                            shed_low_frac=0.25)
    for _ in range(5):
        a.try_admit()
    assert not a.shedding()          # 5 < shed_high=6
    a.try_admit()
    assert a.shedding()              # latched at 6
    a.release(3)
    assert a.shedding()              # 3 is inside the band: sticky
    a.release(1)
    assert not a.shedding()          # 2 <= shed_low=2: cleared
    a.try_admit()
    assert not a.shedding()          # re-latch needs shed_high again


def test_admission_validates_construction():
    with pytest.raises(ValueError):
        AdmissionController(max_depth=0)
    with pytest.raises(ValueError):
        AdmissionController(max_depth=8, shed_low_frac=0.8,
                            shed_high_frac=0.2)


def test_degrade_tier_ladder():
    eps = Guarantee(epsilon=0.5)
    de = degrade_tier(eps)
    assert de.kind == "delta-epsilon"
    assert de.delta == 0.99 and de.epsilon >= 1.0
    assert degrade_tier(Guarantee()).kind == "delta-epsilon"
    ng = degrade_tier(de)
    assert ng.kind == "ng" and ng.nprobe == 16
    assert degrade_tier(ng).nprobe == 8
    assert degrade_tier(Guarantee(nprobe=1)).nprobe == 1  # floor


def test_shed_counts_against_original_kind():
    a = AdmissionController(max_depth=8)
    c = obs.REGISTRY.counter("serve.admission.shed", kind="epsilon")
    c.mark()
    out = a.shed(Guarantee(epsilon=0.5))
    assert out.kind == "delta-epsilon" and c.since_mark == 1
    # bottomed-out tier: no-op, no counter
    c2 = obs.REGISTRY.counter("serve.admission.shed", kind="ng")
    c2.mark()
    assert a.shed(Guarantee(nprobe=1)) == Guarantee(nprobe=1)
    assert c2.since_mark == 0


# ------------------------------------------------------------ scheduler
def test_next_batch_no_starvation_under_small_request_load():
    """Regression: sorted(queues) drained the smallest bucket first,
    so one large request behind sustained small-prompt load NEVER
    drained. Oldest-head-first drains it as soon as its head is the
    longest-waiting."""
    s = Scheduler(max_batch=4, min_bucket=8)
    s.submit(Request(uid=100, prompt=np.arange(20, dtype=np.int32)))
    for uid in range(8):  # sustained small load AFTER the big request
        s.submit(Request(uid=uid, prompt=np.arange(4, dtype=np.int32)))
    bucket, batch = s.next_batch()
    assert bucket == 32 and [r.uid for r in batch] == [100]
    drained = []
    while True:
        nb = s.next_batch()
        if nb is None:
            break
        drained.extend(r.uid for r in nb[1])
    assert drained == list(range(8))


def test_remaining_budget_ms():
    t0 = obs.now()
    r = Request(uid=0, prompt=np.zeros(2, np.int32), deadline_ms=50.0)
    assert remaining_budget_ms(r, r.submitted_at) == pytest.approx(50.0)
    assert remaining_budget_ms(r, r.submitted_at + 0.040) \
        == pytest.approx(10.0, abs=1e-6)
    # spent budgets clamp to ~0, never negative
    assert remaining_budget_ms(r, r.submitted_at + 9.9) == 1e-3
    no_dl = Request(uid=1, prompt=np.zeros(2, np.int32))
    assert remaining_budget_ms(no_dl, t0) is None


def test_retrieval_groups_remap_from_remaining_budget():
    """A 50ms-deadline request that already waited 40ms must drain at
    the tier 10ms affords (ng), NOT the epsilon tier the submitted
    deadline bought; an un-waited twin keeps the full tier."""
    fresh = Request(uid=0, prompt=np.zeros(2, np.int32),
                    deadline_ms=50.0, series=np.zeros(8, np.float32))
    stale = Request(uid=1, prompt=np.zeros(2, np.int32),
                    deadline_ms=50.0, series=np.zeros(8, np.float32))
    now = max(fresh.submitted_at, stale.submitted_at)
    fresh.submitted_at = now               # zero wait: full 50ms left
    stale.submitted_at = now - 0.040        # 40ms already in queue
    by_kind = {g.kind: [r.uid for r in rs]
               for g, rs in retrieval_groups([fresh, stale], at=now)}
    assert by_kind["exact"] == [0]
    assert any(stale.uid in uids and kind == "ng"
               for kind, uids in by_kind.items()), by_kind
    # at=None keeps the pure submitted-deadline mapping: both full tier
    pure = retrieval_groups([fresh, stale], at=None)
    assert len(pure) == 1 and pure[0][0] == guarantee_for_deadline(50.0)


# ---------------------------------------------------------------- front
class _StubEngine:
    """Deterministic engine double: ids[i] = first k multiples of the
    lane's series value; stats=None (resident-style)."""

    def __init__(self, delay_s: float = 0.0):
        self.delay_s = delay_s
        self.calls = []
        self._lock = threading.Lock()

    def query(self, qs, k, g):
        with self._lock:
            self.calls.append((int(qs.shape[0]), g))
        if self.delay_s:
            time.sleep(self.delay_s)
        q = np.asarray(qs)
        b = q.shape[0]
        ids = (q[:, :1].astype(np.int32) * 10
               + np.arange(k, dtype=np.int32))
        return QueryResult(
            dists=jnp.asarray(np.zeros((b, k), np.float32)),
            ids=jnp.asarray(ids),
            leaves_visited=jnp.zeros(b, jnp.int32),
            rows_scanned=jnp.zeros(b, jnp.int32),
            lb_computed=jnp.int32(0), stats=None)


def _req(uid, dl=None, val=None):
    return Request(uid=uid, prompt=np.zeros(2, np.int32),
                   deadline_ms=dl,
                   series=np.full(8, val if val is not None else uid,
                                  np.float32))


def test_lane_of_routing():
    assert lane_of("exact") == "epsilon"
    assert lane_of("epsilon") == "epsilon"
    assert lane_of("delta-epsilon") == "delta-epsilon"
    assert lane_of("ng") == "ng"
    assert set(LANES) == {"epsilon", "delta-epsilon", "ng"}


def test_front_answers_and_releases_admission():
    eng = _StubEngine()
    with ServeFront(eng, k=3, max_batch=4) as front:
        tickets = [front.submit(_req(u, dl)) for u, dl in
                   [(0, None), (1, 30.0), (2, 5.0), (3, None)]]
        outs = {t.uid: t.result(timeout=10.0) for t in tickets}
    assert sorted(outs) == [0, 1, 2, 3]
    for u, o in outs.items():
        assert np.array_equal(o["ids"], u * 10 + np.arange(3)), o
        assert o["latency_ms"] >= o["queue_wait_ms"] >= 0.0
    assert outs[0]["kind"] == "exact"
    assert outs[2]["kind"] == "ng"
    assert front.admission.depth == 0


def test_front_rejects_past_cap():
    # a stalled engine keeps the lane busy while submits pile up
    eng = _StubEngine(delay_s=0.2)
    adm = AdmissionController(max_depth=2)
    front = ServeFront(eng, k=3, max_batch=1, admission=adm).start()
    try:
        t0 = front.submit(_req(0))
        t1 = front.submit(_req(1))
        with pytest.raises(Rejected) as ei:
            front.submit(_req(2))
        assert ei.value.reason == "queue_full"
        assert t0.result(10.0)["ids"] is not None
        assert t1.result(10.0)["ids"] is not None
    finally:
        front.stop()
    # slots freed: a new submit is admitted again
    assert adm.try_admit() is None


def test_front_sheds_one_tier_under_pressure():
    """With shedding latched, a drained exact-tier request is degraded
    one tier (delta-epsilon), flagged on the entry, and counted
    against the ORIGINAL kind."""
    adm = AdmissionController(max_depth=8, shed_high_frac=0.25,
                              shed_low_frac=0.0)
    # latch shedding with phantom depth the front never releases
    adm.try_admit()
    adm.try_admit()
    assert adm.shedding()
    c = obs.REGISTRY.counter("serve.admission.shed", kind="exact")
    c.mark()
    eng = _StubEngine()
    with ServeFront(eng, k=3, admission=adm) as front:
        out = front.submit(_req(0, dl=None)).result(timeout=10.0)
    assert out["shed"] is True
    assert out["nominal_kind"] == "exact"
    assert out["kind"] == "delta-epsilon"
    assert c.since_mark >= 1
    assert all(g.kind == "delta-epsilon" for _b, g in eng.calls)


def test_front_stop_drain_false_fails_pending():
    eng = _StubEngine(delay_s=0.15)
    front = ServeFront(eng, k=3, max_batch=1).start()
    tickets = [front.submit(_req(u)) for u in range(4)]
    front.stop(drain=False)
    outs = [t.result(timeout=10.0) for t in tickets]
    # the in-flight batch completes; the rest fail fast with a reason
    assert any("error" in o for o in outs)
    assert all(o.get("error", "stopped") == "stopped" for o in outs)
    assert front.admission.depth == 0
    with pytest.raises(Rejected):
        front.submit(_req(9))


def test_front_worker_survives_engine_error():
    class Boom(_StubEngine):
        def query(self, qs, k, g):
            if int(np.asarray(qs)[0, 0]) == 7:
                raise RuntimeError("kaboom")
            return super().query(qs, k, g)

    eng = Boom()
    c = obs.REGISTRY.counter("serve.loop.errors", lane="epsilon")
    c.mark()
    with ServeFront(eng, k=3, max_batch=1) as front:
        bad = front.submit(_req(7)).result(timeout=10.0)
        good = front.submit(_req(1)).result(timeout=10.0)
    assert "kaboom" in bad["error"]
    assert np.array_equal(good["ids"], 10 + np.arange(3))
    assert c.since_mark == 1
    assert front.admission.depth == 0


def test_front_no_series_request_completes():
    with ServeFront(_StubEngine(), k=3) as front:
        out = front.submit(Request(
            uid=0, prompt=np.zeros(2, np.int32))).result(timeout=10.0)
    assert out["ids"] is None and out["kind"] == "exact"
    assert out["retrieval_ms"] == 0.0


# --------------------------------------------------------------- stress
@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    data = np.cumsum(rng.normal(size=(N, DIM)), axis=1)
    data = ((data - data.mean(1, keepdims=True))
            / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
    queries = (data[rng.choice(N, 16, replace=False)]
               + 0.05 * rng.normal(size=(16, DIM))).astype(np.float32)
    return data, queries


@pytest.fixture(scope="module")
def spilled_engine(tmp_path_factory, corpus):
    data, _ = corpus
    tmp = str(tmp_path_factory.mktemp("serve_loop_spill"))
    eng = DistributedEngine(mesh=None, method="dstree", shards=SHARDS)
    eng.build(data, index=IndexSpec("dstree", leaf_cap=16),
              store=StoreSpec(spill_dir=tmp, codec="f32",
                              keep_resident=False))
    yield eng
    eng.close()


def test_concurrent_queries_bit_exact_vs_serial(spilled_engine, corpus):
    """The tentpole's re-entrancy contract, engine-level: many
    concurrent query() calls (mixed guarantees, shared warm caches)
    return EXACTLY what serial execution returns — ids and dists."""
    _, queries = corpus
    eng = spilled_engine
    plans = [(jnp.asarray(queries[i:i + 4]), g)
             for i, g in [(0, Guarantee()),
                          (4, Guarantee(epsilon=1.0)),
                          (8, Guarantee(delta=0.99, epsilon=1.0)),
                          (12, Guarantee(nprobe=8)),
                          (2, Guarantee()),
                          (6, Guarantee(nprobe=4))]]
    serial = [eng.query(q, K, g) for q, g in plans]
    for rounds in range(3):  # repeat: interleavings differ per run
        results = [None] * len(plans)
        errs = []

        def worker(i, q, g):
            try:
                results[i] = eng.query(q, K, g)
            except Exception as e:  # noqa: BLE001 — surface thread failures to the main thread's assert instead of dying silently
                errs.append(e)

        ts = [threading.Thread(target=worker, args=(i, q, g))
              for i, (q, g) in enumerate(plans)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs, errs
        for i, res in enumerate(results):
            assert np.array_equal(np.asarray(res.ids),
                                  np.asarray(serial[i].ids)), i
            assert np.array_equal(np.asarray(res.dists),
                                  np.asarray(serial[i].dists)), i
            # stats rode the result, one schema per shard
            assert res.stats is not None
            assert len(res.stats.shards) == SHARDS


def test_front_stress_bit_exact_no_drops_lockorder(corpus,
                                                   tmp_path_factory):
    """The full stack under fire: 4 submitter threads x 24 requests
    through the lanes over a spilled 4-shard store, with the front
    cond, the engine's per-copy locks, the OOC bookkeeping lock, and
    every shard cache/prefetcher lock wrapped in ONE lockorder
    recorder. Every answer must be bit-exact vs the serial oracle for
    its tier; no uid dropped or answered twice; the observed lock
    graph acyclic."""
    data, queries = corpus
    tmp = str(tmp_path_factory.mktemp("stress_spill"))
    eng = DistributedEngine(mesh=None, method="dstree", shards=SHARDS)
    eng.build(data, index=IndexSpec("dstree", leaf_cap=16),
              store=StoreSpec(spill_dir=tmp, codec="f32",
                              keep_resident=False))
    rec = obs.LockOrderRecorder()
    try:
        # no-deadline requests only: every answer is the exact tier,
        # so the serial oracle is ONE engine call per query row
        n_sub, per = 4, 6
        serial = eng.query(jnp.asarray(queries), K, Guarantee())
        s_ids, s_dists = np.asarray(serial.ids), np.asarray(serial.dists)

        # wrap the whole lock surface AFTER the serial warmup built
        # the caches/prefetchers
        eng._ooc_lock = rec.wrap(eng._ooc_lock, "engine._ooc_lock")
        for d in list(eng._copy_locks):
            eng._copy_locks[d] = rec.wrap(eng._copy_locks[d],
                                          f"engine.copy:{d[-8:]}")
        for d, cache in eng._shard_caches.items():
            cache._lock = rec.wrap(cache._lock, f"cache:{d[-8:]}")
            if cache.prefetcher is not None:
                cache.prefetcher._lock = rec.wrap(
                    cache.prefetcher._lock, f"prefetch:{d[-8:]}")

        front = ServeFront(
            eng, K, max_batch=4,
            admission=AdmissionController(max_depth=64),
            lock_recorder=rec).start()
        answers: dict = {}
        answers_lock = threading.Lock()
        errs: list = []

        def submitter(s):
            try:
                tickets = []
                for j in range(per):
                    uid = s * 100 + j
                    qi = (s * per + j) % len(queries)
                    tickets.append((uid, qi, front.submit(Request(
                        uid=uid, prompt=np.zeros(2, np.int32),
                        series=queries[qi]))))
                for uid, qi, t in tickets:
                    out = t.result(timeout=120.0)
                    with answers_lock:
                        assert uid not in answers, f"dup {uid}"
                        answers[uid] = (qi, out)
            except Exception as e:  # noqa: BLE001 — surface thread failures to the main thread's assert instead of dying silently
                errs.append(e)

        subs = [threading.Thread(target=submitter, args=(s,))
                for s in range(n_sub)]
        for t in subs:
            t.start()
        for t in subs:
            t.join()
        front.stop()
        assert not errs, errs
        assert len(answers) == n_sub * per, "dropped uids"
        for uid, (qi, out) in answers.items():
            assert "error" not in out, out
            assert out["kind"] == "exact"
            assert np.array_equal(out["ids"], s_ids[qi]), uid
            assert np.array_equal(out["dists"], s_dists[qi]), uid
        rec.assert_acyclic()
        assert rec.edges(), "recorder saw no lock activity"
    finally:
        eng.close()


# ------------------------------------------------- launch integration
def test_serve_requests_continuous_end_to_end():
    """launch/serve.serve_requests_continuous: decode batches overlap
    continuous retrieval, ticket results merge back per uid, a
    no-series request decodes without a retrieval entry, and an
    admission-rejected request still decodes and surfaces the
    reason."""
    import jax

    from repro.configs import get_smoke_config
    from repro.launch.serve import serve_requests_continuous
    from repro.models import model as M
    from repro.models.params import initialize

    cfg = get_smoke_config("gemma2-2b")
    params = initialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def mk(uid, dl, series):
        return Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, size=6
                                ).astype(np.int32),
            max_new_tokens=3, deadline_ms=dl, series=series)

    reqs = [mk(0, None, np.full(8, 0, np.float32)),
            mk(1, 30.0, np.full(8, 1, np.float32)),
            mk(2, None, None),                      # decode-only
            mk(3, 5.0, np.full(8, 3, np.float32))]
    out = serve_requests_continuous(params, cfg, reqs,
                                    engine=_StubEngine(),
                                    retrieval_k=3, max_batch=2)
    assert sorted(out) == [0, 1, 2, 3]
    for r in out.values():
        assert r["tokens"].shape == (3,)
        assert r["latency_ms"] >= r["queue_wait_ms"] >= 0.0
    assert np.array_equal(out[0]["retrieval"]["ids"], np.arange(3))
    assert out[0]["retrieval"]["nominal_kind"] == "exact"
    assert "retrieval" not in out[2] and out[2]["guarantee"] == "exact"
    assert out[3]["retrieval"]["kind"] == "ng"
    assert out[1]["guarantee"] == out[1]["retrieval"]["kind"]
    assert "deadline_hit" in out[1] and "deadline_hit" in out[3]

    # past the admission cap the request still DECODES; the entry
    # carries the reject reason instead of a retrieval block (the
    # stalled stub keeps the first request in-system so the second
    # submit deterministically hits the cap)
    reqs2 = [mk(10, None, np.full(8, 10, np.float32)),
             mk(11, None, np.full(8, 11, np.float32))]
    out2 = serve_requests_continuous(
        params, cfg, reqs2, engine=_StubEngine(delay_s=0.3),
        retrieval_k=3, max_batch=1,
        admission=AdmissionController(max_depth=1))
    assert out2[11]["retrieval_rejected"] == "queue_full"
    assert out2[11]["tokens"].shape == (3,)
    assert np.array_equal(out2[10]["retrieval"]["ids"],
                          100 + np.arange(3))
