"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes (incl. non-tile-multiples) and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.tier1

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("n_rows", [1, 7, 256, 300])
@pytest.mark.parametrize("n,l", [(64, 16), (256, 16), (96, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paa_matches_ref(n_rows, n, l, dtype):
    x = rand((n_rows, n), dtype)
    got = ops.paa(x, l, force_pallas=True, tile=64)
    want = ref.ref_paa(x, l)
    np.testing.assert_allclose(got, want, atol=2e-2 if
                               dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("b,L,d", [(1, 3, 16), (5, 100, 32), (128, 512, 16),
                                   (9, 700, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_box_mindist_matches_ref(b, L, d, dtype):
    q = rand((b, d), dtype)
    lo = rand((L, d), dtype) - 1.0
    hi = lo + jnp.abs(rand((L, d), dtype))
    w = jnp.abs(rand((d,), jnp.float32)) + 0.5
    got = ops.box_mindist(q, lo, hi, w, force_pallas=True,
                          tile_b=8, tile_l=64)
    want = ref.ref_box_mindist(q, lo, hi, w)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


@pytest.mark.parametrize("b,m,n", [(1, 1, 32), (4, 100, 256),
                                   (130, 257, 100), (8, 64, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_matches_ref(b, m, n, dtype):
    q = rand((b, n), dtype)
    x = rand((m, n), dtype)
    got = ops.l2(q, x, force_pallas=True, tile_b=8, tile_m=64, tile_k=128)
    want = ref.ref_l2(q, x)
    tol = 5e-1 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


def test_l2_padding_never_contaminates():
    """Padded rows/cols must not alter real outputs."""
    q = rand((3, 50))
    x = rand((17, 50))
    got = ops.l2(q, x, force_pallas=True, tile_b=8, tile_m=16, tile_k=64)
    want = ref.ref_l2(q, x)
    np.testing.assert_allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("m_rows,m,k", [(10, 8, 16), (512, 16, 256),
                                        (1000, 4, 64)])
def test_pq_adc_matches_ref(m_rows, m, k):
    codes = jnp.asarray(RNG.integers(0, k, size=(m_rows, m)), jnp.int32)
    lut = jnp.asarray(RNG.uniform(size=(m, k)), jnp.float32)
    got = ops.pq_adc(codes, lut, force_pallas=True, tile_m=128)
    want = ref.ref_pq_adc(codes, lut)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_l2_topk_agrees_with_sort():
    q = rand((4, 64))
    x = rand((200, 64))
    d, i = ops.l2_topk(q, x, 10)
    full = ref.ref_l2(q, x)
    want = jnp.sort(full, axis=1)[:, :10]
    np.testing.assert_allclose(d, want, atol=1e-4)


def test_topk_merge_equals_global_sort():
    d1 = rand((3, 20))
    i1 = jnp.arange(60).reshape(3, 20)
    top_d = jnp.full((3, 5), jnp.inf)
    top_i = jnp.full((3, 5), -1, jnp.int32)
    md, mi = ops.topk_merge(d1, i1, top_d, top_i)
    np.testing.assert_allclose(md, jnp.sort(d1, axis=1)[:, :5], atol=0)


# ------------------------------------- interpret-mode coverage registry
# CI runners have no TPU: interpret mode is the ONLY execution of the
# Pallas kernel bodies there, so EVERY kernel in src/repro/kernels/
# must appear in this registry with an interpret-vs-oracle case. The
# meta test below enumerates the package's ``*_pallas`` entry points
# and fails when a new kernel module lands without one — the sweep
# itself re-runs each case at small shapes (the richer per-kernel
# sweeps live above and in tests/test_topk_select.py).


def _case_paa():
    x = rand((96, 64))
    return ops.paa(x, 8, force_pallas=True, tile=32), ref.ref_paa(x, 8)


def _case_box_mindist():
    q, lo = rand((9, 16)), rand((70, 16)) - 1.0
    hi = lo + jnp.abs(rand((70, 16)))
    w = jnp.abs(rand((16,), jnp.float32)) + 0.5
    return (ops.box_mindist(q, lo, hi, w, force_pallas=True, tile_b=8,
                            tile_l=32),
            ref.ref_box_mindist(q, lo, hi, w))


def _case_l2():
    q, x = rand((5, 96)), rand((67, 96))
    return (ops.l2(q, x, force_pallas=True, tile_b=8, tile_m=32,
                   tile_k=32),
            ref.ref_l2(q, x))


def _case_pq_adc():
    codes = jnp.asarray(RNG.integers(0, 32, size=(200, 8)), jnp.int32)
    lut = jnp.asarray(RNG.uniform(size=(8, 32)), jnp.float32)
    return (ops.pq_adc(codes, lut, force_pallas=True, tile_m=64),
            ref.ref_pq_adc(codes, lut))


def _case_coop_score_select():
    q, rows = rand((5, 32)), rand((96, 32))
    rn = ops.row_sq_norms(rows)
    ids = jnp.asarray(np.arange(96), jnp.int32)
    got = ops.coop_score_select(q, rows, rn, ids, 7,
                                force_pallas=True, tile_b=8, tile_r=32)
    want = ref.ref_coop_score_select(q, rows, rn, ids, 7)
    return jnp.concatenate([got[0], got[1].astype(jnp.float32)], 1), \
        jnp.concatenate([want[0], want[1].astype(jnp.float32)], 1)


def _case_pq_adc_select():
    codes = jnp.asarray(RNG.integers(0, 16, size=(96, 8)), jnp.int32)
    luts = jnp.asarray(RNG.uniform(size=(5, 8, 16)), jnp.float32)
    ids = jnp.asarray(np.arange(96), jnp.int32)
    got = ops.pq_adc_select(codes, luts, ids, 7, force_pallas=True,
                            tile_b=8, tile_r=32)
    want = ref.ref_pq_adc_select(codes, luts, ids, 7)
    return jnp.concatenate([got[0], got[1].astype(jnp.float32)], 1), \
        jnp.concatenate([want[0], want[1].astype(jnp.float32)], 1)


INTERPRET_CASES = {
    "paa_pallas": _case_paa,
    "box_mindist_pallas": _case_box_mindist,
    "l2_pallas": _case_l2,
    "pq_adc_pallas": _case_pq_adc,
    "coop_score_select_pallas": _case_coop_score_select,
    "pq_adc_select_pallas": _case_pq_adc_select,
}


@pytest.mark.parametrize("name", sorted(INTERPRET_CASES))
def test_interpret_mode_parity(name):
    got, want = INTERPRET_CASES[name]()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_every_pallas_kernel_has_interpret_coverage():
    """Enumerate every ``*_pallas`` entry point exported by the kernel
    modules under repro.kernels; each must have an INTERPRET_CASES
    entry so CPU-only CI still executes its kernel body."""
    import importlib
    import pkgutil

    import repro.kernels as kpkg

    found = set()
    for info in pkgutil.iter_modules(kpkg.__path__):
        mod = importlib.import_module(f"repro.kernels.{info.name}")
        found |= {n for n in dir(mod)
                  if n.endswith("_pallas") and callable(getattr(mod, n))}
    assert found, "kernel package exports no *_pallas entry points?"
    missing = found - set(INTERPRET_CASES)
    assert not missing, (
        "Pallas kernels without an interpret-mode parity case: "
        f"{sorted(missing)} — add them to INTERPRET_CASES")
