"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes (incl. non-tile-multiples) and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.tier1

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("n_rows", [1, 7, 256, 300])
@pytest.mark.parametrize("n,l", [(64, 16), (256, 16), (96, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paa_matches_ref(n_rows, n, l, dtype):
    x = rand((n_rows, n), dtype)
    got = ops.paa(x, l, force_pallas=True, tile=64)
    want = ref.ref_paa(x, l)
    np.testing.assert_allclose(got, want, atol=2e-2 if
                               dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("b,L,d", [(1, 3, 16), (5, 100, 32), (128, 512, 16),
                                   (9, 700, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_box_mindist_matches_ref(b, L, d, dtype):
    q = rand((b, d), dtype)
    lo = rand((L, d), dtype) - 1.0
    hi = lo + jnp.abs(rand((L, d), dtype))
    w = jnp.abs(rand((d,), jnp.float32)) + 0.5
    got = ops.box_mindist(q, lo, hi, w, force_pallas=True,
                          tile_b=8, tile_l=64)
    want = ref.ref_box_mindist(q, lo, hi, w)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


@pytest.mark.parametrize("b,m,n", [(1, 1, 32), (4, 100, 256),
                                   (130, 257, 100), (8, 64, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_matches_ref(b, m, n, dtype):
    q = rand((b, n), dtype)
    x = rand((m, n), dtype)
    got = ops.l2(q, x, force_pallas=True, tile_b=8, tile_m=64, tile_k=128)
    want = ref.ref_l2(q, x)
    tol = 5e-1 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


def test_l2_padding_never_contaminates():
    """Padded rows/cols must not alter real outputs."""
    q = rand((3, 50))
    x = rand((17, 50))
    got = ops.l2(q, x, force_pallas=True, tile_b=8, tile_m=16, tile_k=64)
    want = ref.ref_l2(q, x)
    np.testing.assert_allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("m_rows,m,k", [(10, 8, 16), (512, 16, 256),
                                        (1000, 4, 64)])
def test_pq_adc_matches_ref(m_rows, m, k):
    codes = jnp.asarray(RNG.integers(0, k, size=(m_rows, m)), jnp.int32)
    lut = jnp.asarray(RNG.uniform(size=(m, k)), jnp.float32)
    got = ops.pq_adc(codes, lut, force_pallas=True, tile_m=128)
    want = ref.ref_pq_adc(codes, lut)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_l2_topk_agrees_with_sort():
    q = rand((4, 64))
    x = rand((200, 64))
    d, i = ops.l2_topk(q, x, 10)
    full = ref.ref_l2(q, x)
    want = jnp.sort(full, axis=1)[:, :10]
    np.testing.assert_allclose(d, want, atol=1e-4)


def test_topk_merge_equals_global_sort():
    d1 = rand((3, 20))
    i1 = jnp.arange(60).reshape(3, 20)
    top_d = jnp.full((3, 5), jnp.inf)
    top_i = jnp.full((3, 5), -1, jnp.int32)
    md, mi = ops.topk_merge(d1, i1, top_d, top_i)
    np.testing.assert_allclose(md, jnp.sort(d1, axis=1)[:, :5], atol=0)
