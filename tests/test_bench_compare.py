"""benchmarks/compare.py gating semantics over hand-built snapshots.

The bench gate is pure dict-in / failures-out (``compare(base, fresh,
same_scale=...)``), so its tolerance policy — the thing CI trusts to
catch a perf regression — is unit-testable without running a single
benchmark. These tests pin the PR 9 ``serve_load`` rules and the
cross-scale ratio floor.
"""

import pytest

from benchmarks import compare as C

pytestmark = pytest.mark.tier1


def _point(lf, static_p99, cont_p99, *, static_deg=0.4, cont_deg=0.4):
    return {
        "load_factor": lf,
        "static": {"p50_ms": static_p99 / 2, "p99_ms": static_p99,
                   "degraded_frac": static_deg},
        "continuous": {"p50_ms": cont_p99 / 2, "p99_ms": cont_p99,
                       "degraded_frac": cont_deg},
    }


def _snap(*, beats=True, points=None):
    points = points if points is not None else [_point(4.0, 1000.0, 800.0)]
    top = points[-1]
    return {
        "serve_load": {
            "points": points,
            "summary": {
                "top_load_factor": top["load_factor"],
                "static_p99_ms": top["static"]["p99_ms"],
                "continuous_p99_ms": top["continuous"]["p99_ms"],
                "continuous_beats_static": beats,
            },
        },
    }


def _failed(base, fresh, *, same_scale):
    failures, _lines = C.compare(base, fresh, same_scale=same_scale)
    return failures


def test_baseline_flag_checked_even_cross_scale():
    # a committed curve where continuous LOSES must fail the gate no
    # matter what scale the fresh run collected at
    fails = _failed(_snap(beats=False), _snap(), same_scale=False)
    assert any("continuous_beats_static[baseline]" in f for f in fails)
    assert not _failed(_snap(), _snap(), same_scale=False)


def test_fresh_flag_enforced_only_same_scale():
    # at the small smoke scale engine calls are cheap enough that
    # front overhead, not queueing, dominates p99 — the fresh flag is
    # only meaningful at the baseline's own scale
    losing = _snap(beats=False)
    assert not [f for f in _failed(_snap(), losing, same_scale=False)
                if f == "serve_load/continuous_beats_static"]
    fails = _failed(_snap(), losing, same_scale=True)
    assert "serve_load/continuous_beats_static" in fails


def test_per_point_p99_ceiling_and_degraded_band():
    base = _snap()
    slow = _snap(points=[_point(
        4.0, 1000.0 * C.TIME_FACTOR * 1.1, 800.0)])
    fails = _failed(base, slow, same_scale=True)
    assert "serve_load/x4.0/static/p99_ms" in fails
    shifted = _snap(points=[_point(
        4.0, 1000.0, 800.0, cont_deg=0.4 + C.DEGRADED_TOL + 0.01)])
    fails = _failed(base, shifted, same_scale=True)
    assert "serve_load/x4.0/continuous/degraded_frac" in fails
    within = _snap(points=[_point(
        4.0, 1000.0 * 1.5, 800.0, cont_deg=0.4 + C.DEGRADED_TOL / 2)])
    assert not _failed(base, within, same_scale=True)


def test_missing_load_point_fails_same_scale():
    base = _snap(points=[_point(1.0, 500.0, 400.0),
                         _point(4.0, 1000.0, 800.0)])
    fresh = _snap(points=[_point(4.0, 1000.0, 800.0)])
    fails = _failed(base, fresh, same_scale=True)
    assert "serve_load/x1.0" in fails


def test_ratio_floor_loosens_cross_scale():
    # the sort references grow superlinearly with scale, the fused
    # paths don't — so a small-scale fresh run legitimately keeps
    # less than RATIO_KEEP of a default-scale baseline's ratio, while
    # a silent fallback to the full-sort path (ratio ~1x) still trips
    base = {"merge_speedup_vs_full_sort": {"topk_merge_speedup": 100.0}}
    mid = {"merge_speedup_vs_full_sort": {"topk_merge_speedup":
           100.0 * (C.RATIO_KEEP + C.CROSS_SCALE_RATIO_KEEP) / 2}}
    assert _failed(base, mid, same_scale=True)
    assert not _failed(base, mid, same_scale=False)
    fallback = {"merge_speedup_vs_full_sort": {"topk_merge_speedup": 1.0}}
    assert _failed(base, fallback, same_scale=False)
