"""PR 8 fault tolerance: injection, failover, honest degradation.

Four layers, all in-process and mesh-free (``DistributedEngine``
with ``mesh=None`` + ``shards=N`` is the single-process stand-in for
per-host shard ownership, so the chaos paths run in tier1 time):

  units      FaultInjector rule semantics, RetryPolicy backoff,
             CircuitBreaker state machine, serve_shard_with_failover,
             effective_delta_after_loss math vs a manual recompute.
  engine     concurrent shard owners == sequential fold == brute
             force; a shard killed past retries AND replicas degrades
             the answer to a bit-exact surviving-shards fold with the
             recomputed delta; the same kill aimed only at the owner
             copy fails over and returns the FULL undegraded answer.
  lifecycle  close() idempotent, close() racing an in-flight query,
             re-opened engines bit-exact; prefetcher deadline/close
             paths SURFACE (counters + warnings) instead of silently
             returning None.
  serving    Scheduler.run_retrieval / Supervisor surface the same
             events (degraded entries, train.restarts counter, the
             history clamp for pre-dated checkpoints).
"""

import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import search as S
from repro.core import IndexSpec, StoreSpec
from repro.core.engine import DistributedEngine
from repro.core.guarantees import Guarantee, effective_delta_after_loss
from repro.fault import FaultInjected, FaultInjector
from repro.serve.fault import (CircuitBreaker, FaultContext, RetryPolicy,
                               ShardLost, ShardTimeout,
                               serve_shard_with_failover)

pytestmark = pytest.mark.tier1

N, DIM, SHARDS, K = 512, 32, 4, 5


# ---------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    data = np.cumsum(rng.normal(size=(N, DIM)), axis=1)
    data = ((data - data.mean(1, keepdims=True))
            / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
    queries = (data[rng.choice(N, 4, replace=False)]
               + 0.05 * rng.normal(size=(4, DIM))).astype(np.float32)
    return data, queries


@pytest.fixture(scope="module")
def spill(tmp_path_factory, corpus):
    """One spilled 4-shard build with replicas=2 — every copy is a
    byte-identical store, so both the failover and the degradation
    tests share the artifact."""
    data, _ = corpus
    tmp = str(tmp_path_factory.mktemp("fault_spill"))
    eng = DistributedEngine(mesh=None, method="dstree", shards=SHARDS)
    eng.build(data, index=IndexSpec("dstree", leaf_cap=16),
              store=StoreSpec(spill_dir=tmp, codec="f32",
                              keep_resident=False, replicas=2))
    eng.close()
    return tmp


@pytest.fixture()
def engine(spill):
    eng = DistributedEngine.open_spill(
        StoreSpec(spill_dir=spill, keep_resident=False))
    yield eng
    eng.close()


def surviving_oracle(data, queries, k, lost):
    """Brute force over every row NOT owned by a lost shard, with ids
    mapped back to global — THE answer a degraded query must equal."""
    n = data.shape[0]
    bounds = np.linspace(0, n, SHARDS + 1).astype(np.int64)
    mask = np.ones(n, bool)
    for si in lost:
        mask[bounds[si]:bounds[si + 1]] = False
    ids_map = np.where(mask)[0]
    bf = S.brute_force(jnp.asarray(queries),
                       jnp.asarray(data[mask]), k)
    return ids_map[np.asarray(bf.ids)], np.asarray(bf.dists)


# ------------------------------------------------------- injector units
def test_injector_times_and_after():
    inj = FaultInjector().fail("gather", shard=1, times=2, after=1)
    inj.check("gather", shard=1)  # 'after' swallows the first match
    for _ in range(2):
        with pytest.raises(FaultInjected):
            inj.check("gather", shard=1)
    inj.check("gather", shard=1)  # times exhausted
    inj.check("gather", shard=0)  # other shard never matched
    inj.check("score", shard=1)   # other point never matched


def test_injector_wildcard_and_replica_position():
    inj = FaultInjector().kill_shard(2, replica=0)
    with pytest.raises(FaultInjected):
        inj.check("shard", shard=2, replica=0)
    with pytest.raises(FaultInjected):  # permanent: fires again
        inj.check("gather", shard=2, replica=0)
    inj.check("gather", shard=2, replica=1)  # non-owner copy survives
    inj.clear()
    inj.check("shard", shard=2, replica=0)


def test_injector_delay_sleeps_instead_of_raising():
    c = obs.REGISTRY.counter("fault.delayed", point="gather", shard="3")
    c.mark()
    inj = FaultInjector().delay("gather", shard=3, seconds=0.002,
                                times=1)
    t0 = obs.now()
    inj.check("gather", shard=3)  # sleeps, does not raise
    assert obs.now() - t0 >= 0.002
    assert c.since_mark == 1
    inj.check("gather", shard=3)  # times exhausted: no sleep


def test_injector_training_backcompat():
    from repro.train.fault import FaultInjector as TrainInjector
    assert TrainInjector is FaultInjector  # one shared class
    inj = FaultInjector(fail_at=[12])
    inj.maybe_fail(11)
    with pytest.raises(RuntimeError, match="step 12"):
        inj.maybe_fail(12)
    inj.maybe_fail(12)  # fires once per step, exactly as before


# ------------------------------------------------- policy/breaker units
def test_retry_policy_backoff_caps():
    p = RetryPolicy(backoff_base_s=0.01, backoff_cap_s=0.04)
    assert p.backoff_s(0) == 0.01
    assert p.backoff_s(1) == 0.02
    assert p.backoff_s(10) == 0.04  # capped


def test_circuit_breaker_opens_half_opens_reopens(monkeypatch):
    t = [0.0]
    monkeypatch.setattr(obs, "now", lambda: t[0])
    br = CircuitBreaker(threshold=2, cooldown_s=10.0)
    key = (0, "copyA")
    br.record_failure(key)
    assert br.allow(key)          # below threshold
    br.record_failure(key)
    assert not br.allow(key)      # open
    t[0] = 11.0
    assert br.allow(key)          # cooldown elapsed: half-open probe
    br.record_failure(key)        # failed probe re-opens IMMEDIATELY
    assert not br.allow(key)
    t[0] = 22.0
    assert br.allow(key)
    br.record_success(key)        # successful probe fully resets
    br.record_failure(key)
    assert br.allow(key)          # needs threshold failures again


def test_fault_context_deadline_raises_shard_timeout():
    ctx = FaultContext(shard=0, deadline=obs.now() - 1.0)
    with pytest.raises(ShardTimeout):
        ctx.check("gather")


# --------------------------------------------- failover-loop units
def test_failover_retries_then_serves_replica(tmp_path):
    calls = []

    def attempt(d, ctx):
        calls.append((d, ctx.replica))
        if ctx.replica == 0:
            raise RuntimeError("owner down")
        return f"served:{d}"

    c_fail = obs.REGISTRY.counter("fault.attempt_failed", shard="7")
    c_over = obs.REGISTRY.counter("fault.failovers", shard="7")
    c_fail.mark()
    c_over.mark()
    hist = obs.REGISTRY.histogram("fault.failover_latency_ms",
                                  shard="7")
    n0 = hist.count
    out, info = serve_shard_with_failover(
        attempt, shard=7, replica_dirs=("a", "b"),
        policy=RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    assert out == "served:b"
    assert (info.retries, info.failovers, info.served_replica) == \
        (1, 1, 1)
    assert calls == [("a", 0), ("b", 1)]
    assert c_fail.since_mark == 1 and c_over.since_mark == 1
    assert hist.count == n0 + 1


def test_failover_exhaustion_raises_shard_lost():
    c = obs.REGISTRY.counter("fault.shard_lost", shard="9")
    c.mark()

    def attempt(d, ctx):
        raise ValueError("always")

    with pytest.raises(ShardLost) as exc:
        serve_shard_with_failover(
            attempt, shard=9, replica_dirs=("only",),
            policy=RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    assert exc.value.shard == 9
    assert isinstance(exc.value.cause, ValueError)
    assert c.since_mark == 1


def test_failover_skips_open_circuit():
    br = CircuitBreaker(threshold=1, cooldown_s=1000.0)
    br.record_failure((5, "a"))  # circuit for the owner copy is open
    c = obs.REGISTRY.counter("fault.breaker_skip", shard="5")
    c.mark()
    served = []

    def attempt(d, ctx):
        served.append(d)
        return d

    out, info = serve_shard_with_failover(
        attempt, shard=5, replica_dirs=("a", "b"), breaker=br,
        policy=RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    assert out == "b" and served == ["b"]
    assert info.failovers == 1
    assert c.since_mark == 1


def test_every_attempt_budget_covers_all_replicas():
    # max_attempts=1 but 3 copies: every copy still gets a shot
    seen = []

    def attempt(d, ctx):
        seen.append(d)
        if len(seen) < 3:
            raise RuntimeError("nope")
        return d

    out, _ = serve_shard_with_failover(
        attempt, shard=0, replica_dirs=("a", "b", "c"),
        policy=RetryPolicy(max_attempts=1, backoff_base_s=0.0))
    assert out == "c" and seen == ["a", "b", "c"]


# ------------------------------------------------- degradation math
def test_effective_delta_after_loss_math(spill):
    from repro.core.histogram import f_of
    from repro.store import load_index
    store = load_index(os.path.join(spill, "shard_0000"),
                       resident="summaries")
    hist = store.resident.hist
    kth = np.asarray([0.5, 1.0, 2.0], np.float64)
    delta, eps, n_lost = 0.9, 0.5, 128
    got = effective_delta_after_loss(hist, kth, n_lost, delta=delta,
                                     epsilon=eps)
    p_hit = np.asarray(f_of(hist, kth / (1 + eps)), np.float64)
    want = delta * np.min((1 - p_hit) ** n_lost)
    np.testing.assert_allclose(got, want, rtol=1e-12)
    # boundary cases: nothing lost -> prior delta; an unfilled lane
    # (inf kth) kills every probabilistic claim
    assert effective_delta_after_loss(hist, kth, 0, delta=delta) == delta
    assert effective_delta_after_loss(
        hist, np.asarray([np.inf]), 1, delta=delta) == 0.0


# ------------------------------------------------- engine: no faults
def test_concurrent_owners_match_brute_force(corpus, engine):
    data, queries = corpus
    bf = S.brute_force(jnp.asarray(queries), jnp.asarray(data), K)
    res = engine.query(jnp.asarray(queries), K, Guarantee())
    assert np.array_equal(np.asarray(res.ids), np.asarray(bf.ids))
    st = res.stats
    assert st is not None and not st.degraded
    assert st.effective_delta == 1.0 and st.shards_lost == 0
    assert len(st.shards) == SHARDS
    # completion-order independence: the sequential fold is bit-exact
    seq = engine.query(jnp.asarray(queries), K, Guarantee(),
                       ooc_opts={"workers": 1})
    assert np.array_equal(np.asarray(res.ids), np.asarray(seq.ids))
    assert np.array_equal(np.asarray(res.dists), np.asarray(seq.dists))


# ------------------------------------------------- engine: chaos
def test_shard_killed_past_replicas_degrades_bit_exact(corpus, engine):
    data, queries = corpus
    lost_shard = 1
    inj = FaultInjector().kill_shard(lost_shard)  # every copy, forever
    c_deg = obs.REGISTRY.counter("engine.degraded_queries")
    c_lost = obs.REGISTRY.counter("engine.shards_lost")
    c_deg.mark()
    c_lost.mark()
    with pytest.warns(UserWarning, match="lost past retries"):
        res = engine.query(
            jnp.asarray(queries), K, Guarantee(),
            ooc_opts={"fault": inj,
                      "retry": RetryPolicy(max_attempts=2,
                                           backoff_base_s=0.0)})
    st = res.stats
    assert st.degraded and st.shards_lost == 1
    # bit-exact against the surviving-shards oracle
    o_ids, o_dists = surviving_oracle(data, queries, K, [lost_shard])
    assert np.array_equal(np.asarray(res.ids), o_ids)
    # ids are exact; dists take a different accumulation path than
    # the brute-force oracle (per-leaf device scoring), so compare to
    # float32 accumulation tolerance
    np.testing.assert_allclose(np.asarray(res.dists), o_dists,
                               rtol=1e-4, atol=1e-4)
    # the reported delta IS the histogram recomputation, n_lost = the
    # killed shard's row count
    from repro.store import load_index
    hist = load_index(os.path.join(
        engine.shard_dirs[0]), resident="summaries").resident.hist
    want = effective_delta_after_loss(
        hist, np.asarray(res.dists[:, K - 1]), N // SHARDS,
        delta=1.0, epsilon=0.0)
    assert st.effective_delta == want
    assert 0.0 <= st.effective_delta < 1.0
    assert c_deg.since_mark == 1 and c_lost.since_mark == 1
    # the injector's firings were recorded
    assert obs.REGISTRY.counter(
        "fault.injected", point="shard",
        shard=str(lost_shard)).value >= 1


def test_owner_kill_fails_over_to_replica_full_answer(corpus, engine):
    data, queries = corpus
    clean = engine.query(jnp.asarray(queries), K, Guarantee())
    inj = FaultInjector().kill_shard(1, replica=0)  # owner copy only
    c_over = obs.REGISTRY.counter("fault.failovers", shard="1")
    c_over.mark()
    res = engine.query(
        jnp.asarray(queries), K, Guarantee(),
        ooc_opts={"fault": inj,
                  "retry": RetryPolicy(max_attempts=2,
                                       backoff_base_s=0.0)})
    st = res.stats
    assert not st.degraded and st.shards_lost == 0
    assert st.failovers >= 1 and st.retries >= 1
    assert c_over.since_mark >= 1
    # the replica is byte-identical: full answer, bit for bit
    assert np.array_equal(np.asarray(res.ids), np.asarray(clean.ids))
    assert np.array_equal(np.asarray(res.dists),
                          np.asarray(clean.dists))


def test_slow_owner_deadline_fails_over(corpus, engine):
    data, queries = corpus
    clean = engine.query(jnp.asarray(queries), K, Guarantee())
    # one oversized stall on the OWNER copy's first gather; the
    # deadline is generous for healthy shards (their attempts run in
    # milliseconds on warm jits) but the stalled attempt overruns it
    # at the very next cooperative check and fails over
    inj = FaultInjector().delay("gather", shard=2, replica=0,
                                seconds=0.4, times=1)
    res = engine.query(
        jnp.asarray(queries), K, Guarantee(),
        ooc_opts={"fault": inj,
                  "retry": RetryPolicy(max_attempts=2,
                                       backoff_base_s=0.0,
                                       attempt_deadline_s=0.3)})
    st = res.stats
    assert not st.degraded and st.failovers >= 1
    assert np.array_equal(np.asarray(res.ids), np.asarray(clean.ids))


def test_mid_query_kill_degrades(corpus, engine):
    """The kill lands AFTER the shard did real work (after=1 skips the
    first gather), on every copy — the answer must still be the exact
    surviving-shards fold."""
    data, queries = corpus
    inj = FaultInjector().fail("gather", shard=2, after=1,
                               times=np.inf)
    with pytest.warns(UserWarning, match="lost past retries"):
        res = engine.query(
            jnp.asarray(queries), K, Guarantee(),
            ooc_opts={"fault": inj,
                      "retry": RetryPolicy(max_attempts=2,
                                           backoff_base_s=0.0)})
    assert res.stats.degraded
    o_ids, _ = surviving_oracle(data, queries, K, [2])
    assert np.array_equal(np.asarray(res.ids), o_ids)


def test_all_shards_lost_raises(corpus, engine):
    _, queries = corpus
    inj = FaultInjector()
    for si in range(SHARDS):
        inj.kill_shard(si)
    with pytest.raises(ShardLost, match="every shard"):
        engine.query(
            jnp.asarray(queries), K, Guarantee(),
            ooc_opts={"fault": inj,
                      "retry": RetryPolicy(max_attempts=2,
                                           backoff_base_s=0.0)})


# ------------------------------------------------- engine lifecycle
def test_close_idempotent_and_rebuild_bit_exact(corpus, spill, engine):
    _, queries = corpus
    first = engine.query(jnp.asarray(queries), K, Guarantee())
    engine.close()
    engine.close()  # idempotent
    again = engine.query(jnp.asarray(queries), K, Guarantee())
    assert np.array_equal(np.asarray(first.ids), np.asarray(again.ids))
    fresh = DistributedEngine.open_spill(
        StoreSpec(spill_dir=spill, keep_resident=False))
    try:
        re = fresh.query(jnp.asarray(queries), K, Guarantee())
        assert np.array_equal(np.asarray(first.ids),
                              np.asarray(re.ids))
        assert np.array_equal(np.asarray(first.dists),
                              np.asarray(re.dists))
    finally:
        fresh.close()


def test_close_racing_inflight_query(corpus, engine):
    """close() from another thread mid-query: the query keeps its own
    cache references and must finish with the correct answer."""
    data, queries = corpus
    bf = S.brute_force(jnp.asarray(queries), jnp.asarray(data), K)
    inj = FaultInjector().delay("score", seconds=0.005)  # slow it down
    out, err = [], []

    def run():
        try:
            out.append(engine.query(jnp.asarray(queries), K,
                                    Guarantee(),
                                    ooc_opts={"fault": inj}))
        except BaseException as e:  # re-raised on the main thread below
            err.append(e)

    th = threading.Thread(target=run)
    th.start()
    time.sleep(0.01)
    engine.close()  # lands mid-query (or harmlessly after)
    th.join(timeout=60)
    assert not th.is_alive()
    assert not err, err
    assert np.array_equal(np.asarray(out[0].ids), np.asarray(bf.ids))


# ------------------------------------------------- prefetcher surfacing
class _BlockingStore:
    """Minimal LeafStore stand-in whose read_leaf blocks on an event —
    drives the prefetcher's deadline/close paths deterministically."""

    def __init__(self):
        self.release = threading.Event()

    def read_leaf(self, leaf):
        self.release.wait(timeout=30)
        return np.zeros((4, 4), np.float32)

    def leaf_nbytes(self, leaf):
        return 64


def _quiesce_counter(p, site):
    return obs.REGISTRY.counter("store.prefetch.quiesce_timeout",
                                site=site, prefetch=p.name)


def test_prefetch_take_deadline_is_surfaced():
    from repro.store import LeafPrefetcher
    store = _BlockingStore()
    p = LeafPrefetcher(store, depth=2)
    try:
        p.schedule([0])
        c = _quiesce_counter(p, "take")
        c.mark()
        with pytest.warns(RuntimeWarning, match="gave up"):
            assert p.take(0, timeout=0.02) is None
        assert c.since_mark == 1
        # an UNSCHEDULED leaf is a silent None — no false positive
        c.mark()
        assert p.take(99, timeout=0.02) is None
        assert c.since_mark == 0
    finally:
        store.release.set()
        p.close()


def test_prefetch_reset_quiesce_timeout_is_surfaced():
    from repro.store import LeafPrefetcher
    store = _BlockingStore()
    p = LeafPrefetcher(store, depth=2)
    try:
        p.schedule([0])
        deadline = obs.now() + 5
        while p._reading is None:  # wait for the read to start
            assert obs.now() < deadline
            time.sleep(0.001)
        c = _quiesce_counter(p, "reset")
        c.mark()
        with pytest.warns(RuntimeWarning, match="quiesce timed out"):
            p.reset_counters(timeout=0.02)
        assert c.since_mark == 1
    finally:
        store.release.set()
        p.close()


def test_prefetch_close_leak_is_surfaced():
    from repro.store import LeafPrefetcher
    store = _BlockingStore()
    p = LeafPrefetcher(store, depth=2)
    p.schedule([0])
    deadline = obs.now() + 5
    while p._reading is None:
        assert obs.now() < deadline
        time.sleep(0.001)
    c = obs.REGISTRY.counter("store.prefetch.close_leaked",
                             prefetch=p.name)
    c.mark()
    with pytest.warns(RuntimeWarning, match="still alive"):
        p.close(timeout=0.02)
    assert c.since_mark == 1
    store.release.set()  # let the daemon thread drain


# ------------------------------------------------- supervisor surfacing
def _trivial_sup(ckpt, **kw):
    from repro.train.fault import Supervisor

    def train_step(params, opt_state, batch):
        return params, opt_state, {"loss": float(batch)}

    return Supervisor(train_step, lambda step: float(step), ckpt,
                      **kw)


def test_supervisor_history_clamped_for_predated_checkpoint(tmp_path):
    """A checkpoint PREDATING start_step (left by an earlier run of
    the same dir) used to make the restore slice negative and the
    replayed steps double-append — the loss history must be exactly
    this run's steps."""
    from repro.train.checkpoint import Checkpointer
    ck = Checkpointer(str(tmp_path))
    _trivial_sup(ck, ckpt_every=3).run(
        np.zeros(2, np.float32), np.zeros(2, np.float32), 0, 4)
    ck.wait()
    assert ck.latest_step() == 3  # predates the next run's start
    c = obs.REGISTRY.counter("train.restarts")
    c.mark()
    out = _trivial_sup(
        Checkpointer(str(tmp_path)), ckpt_every=100,
        injector=FaultInjector(fail_at=[8])).run(
            np.zeros(2, np.float32), np.zeros(2, np.float32), 5, 5)
    assert out["restarts"] == 1
    assert c.since_mark == 1
    assert out["losses"] == [5.0, 6.0, 7.0, 8.0, 9.0]


def test_supervisor_straggler_counter(tmp_path):
    from repro.train.checkpoint import Checkpointer

    def make_batch(step):
        if step == 2:
            time.sleep(0.02)
        return float(step)

    from repro.train.fault import Supervisor

    def train_step(params, opt_state, batch):
        return params, opt_state, {"loss": float(batch)}

    c = obs.REGISTRY.counter("train.stragglers")
    c.mark()
    out = Supervisor(train_step, make_batch,
                     Checkpointer(str(tmp_path)), ckpt_every=100,
                     straggler_factor=1.5).run(
        np.zeros(2, np.float32), np.zeros(2, np.float32), 0, 4)
    assert out["stragglers"] >= 1
    assert c.since_mark == out["stragglers"]


# ------------------------------------------------- serving surfacing
def test_run_retrieval_surfaces_degradation():
    from repro.core.engine import QueryResult
    from repro.obs import OocStats
    from repro.serve.batching import Request, Scheduler

    class StubEngine:
        """Stats ride the RESULT (QueryResult.stats) — the serving
        front must never read them off the engine (engine-stats
        analysis rule)."""

        def __init__(self, stats):
            self._stats = stats

        def query(self, qs, k, g):
            b = qs.shape[0]
            return QueryResult(
                dists=jnp.zeros((b, k), jnp.float32),
                ids=jnp.zeros((b, k), jnp.int32),
                leaves_visited=jnp.zeros(b, jnp.int32),
                rows_scanned=jnp.zeros(b, jnp.int32),
                lb_computed=jnp.int32(0),
                stats=self._stats)

    reqs = [Request(uid=0, prompt=np.zeros(4, np.int32),
                    series=np.zeros(DIM, np.float32))]
    st = OocStats(degraded=True, shards_lost=1, effective_delta=0.42)
    c = obs.REGISTRY.counter("serve.degraded", kind="exact")
    c.mark()
    out = Scheduler().run_retrieval(StubEngine(st), reqs, k=3)
    e = out[0]
    assert e["degraded"] and e["kind"] == "delta-epsilon"
    assert e["requested_kind"] == "exact"
    assert e["effective_delta"] == 0.42 and e["shards_lost"] == 1
    assert c.since_mark == 1
    # undegraded stats leave the entry untouched
    out = Scheduler().run_retrieval(StubEngine(OocStats()), reqs, k=3)
    assert out[0]["kind"] == "exact" and "degraded" not in out[0]
