"""Observability layer (repro.obs): tracer, metrics registry, OocStats
schema, and the span-vs-counter no-drift contract on a real query.

The histogram quantile property test pins the documented resolution
claim: any quantile is within one log bucket (factor GROWTH ~ 1.09) of
the true sample quantile at the same rank convention
(numpy.quantile(..., method="lower")), and exactly inside [min, max].
"""

import json
import threading
import time

import numpy as np
import pytest
from _hyp import given, settings, st

from repro import obs
from repro.core import guarantees as G
from repro.core import search as S
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree
from repro.obs import GROWTH, Histogram, MetricsRegistry, OocStats

pytestmark = pytest.mark.tier1

SETTINGS = dict(max_examples=40, deadline=None)


@pytest.fixture
def traced():
    """Enable tracing for one test, restore + clear afterwards."""
    obs.clear()
    obs.enable()
    yield obs.tracer()
    obs.disable()
    obs.clear()


# ------------------------------------------------------------- tracer
def test_disabled_span_is_shared_noop():
    assert not obs.enabled()
    sp = obs.span("x", a=1)
    assert sp is obs.NULL_SPAN
    with sp as s:
        s.set(bytes_read=5)
        s.add("bytes_read", 5)
    assert obs.tracer().spans() == []


def test_span_nesting_and_profile(traced):
    with obs.span("root", k=5) as root:
        with obs.span("filter"):
            time.sleep(0.001)
        for i in range(3):
            with obs.span("iter", n=i) as it:
                it.set(bytes=10 * (i + 1))
    spans = traced.spans()
    # completion order: children land before their parent
    assert [s.name for s in spans] == ["filter", "iter", "iter",
                                       "iter", "root"]
    assert all(s.parent == root.id for s in spans[:-1])
    assert root.parent == -1
    prof = obs.last_profile("root")
    assert prof.attrs == {"k": 5}
    assert prof.count("iter") == 3
    assert prof.total("bytes") == 60
    assert set(prof.phase_ms) == {"filter", "iter"}
    assert prof.phase_ms["filter"] >= 1.0
    assert prof.duration_ms >= prof.phase_ms["filter"]


def test_subtree_isolates_concurrent_roots(traced):
    with obs.span("query") as q1:
        with obs.span("gather") as g1:
            pass
    with obs.span("query"):
        with obs.span("gather"):
            pass
    sub = traced.subtree(q1)
    assert {s.id for s in sub} == {q1.id, g1.id}


def test_threads_build_independent_subtrees(traced):
    barrier = threading.Barrier(2)
    roots = {}

    def work(tag):
        barrier.wait()
        with obs.span("troot", tag=tag) as r:
            with obs.span("tchild", tag=tag):
                pass
        roots[tag] = r

    ts = [threading.Thread(target=work, args=(t,)) for t in ("a", "b")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for tag in ("a", "b"):
        assert roots[tag].parent == -1
        (child,) = [s for s in traced.find("tchild")
                    if s.attrs["tag"] == tag]
        assert child.parent == roots[tag].id
        assert child.tid == roots[tag].tid


def test_chrome_events_structure(tmp_path, traced):
    with obs.span("outer", codec="f32"):
        with obs.span("inner") as sp:
            sp.set(n=np.int64(7))  # numpy scalars must JSON-ify
    path = obs.dump_chrome_trace(str(tmp_path / "t.json"))
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    assert len(evs) == 2
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in evs)
    inner = next(e for e in evs if e["name"] == "inner")
    outer = next(e for e in evs if e["name"] == "outer")
    assert inner["args"]["n"] == 7
    assert outer["args"]["codec"] == "f32"
    # child event nests inside its parent on the shared clock
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1


# ----------------------------------------------------------- registry
def test_registry_label_keying_and_kind_conflict():
    reg = MetricsRegistry()
    a = reg.counter("reads", shard="0", codec="pq")
    b = reg.counter("reads", codec="pq", shard="0")  # order-insensitive
    c = reg.counter("reads", shard="1", codec="pq")
    assert a is b and a is not c
    a.inc(3)
    assert b.value == 3 and c.value == 0
    with pytest.raises(TypeError):
        reg.histogram("reads", shard="0", codec="pq")
    g = reg.gauge("depth")
    g.set(4)
    snap = reg.snapshot()
    assert snap["reads{codec=pq,shard=0}"] == 3
    assert snap["depth"] == 4
    assert len(reg.collect("reads")) == 2


def test_counter_window_marks_keep_lifetime_total():
    reg = MetricsRegistry()
    ctr = reg.counter("bytes")
    ctr.inc(100)
    ctr.mark()
    ctr.inc(7)
    assert ctr.since_mark == 7
    assert ctr.value == 107  # the registry never forgets


# ---------------------------------------------------------- histogram
def test_histogram_empty_and_singleton():
    h = Histogram("h", ())
    assert np.isnan(h.quantile(0.5))
    h.record(3.7)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.quantile(q) == 3.7  # clamped to [min, max] = point
    snap = h.snapshot()
    assert snap["count"] == 1 and snap["p50"] == 3.7


@given(st.lists(st.floats(1e-6, 1e6), min_size=1, max_size=100),
       st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_histogram_quantile_vs_numpy(xs, q):
    h = Histogram("h", ())
    for v in xs:
        h.record(v)
    got = h.quantile(q)
    x = np.asarray(xs, np.float64)
    # same rank convention as the histogram: value at floor(q*(n-1))
    ref = float(np.quantile(x, q, method="lower"))
    tol = GROWTH * (1 + 1e-9)
    assert ref / tol <= got <= ref * tol
    assert x.min() <= got <= x.max()


@given(st.lists(st.floats(1e-6, 1e6), min_size=2, max_size=60))
@settings(**SETTINGS)
def test_histogram_quantiles_monotone(xs):
    h = Histogram("h", ())
    for v in xs:
        h.record(v)
    qs = [h.quantile(q) for q in (0.0, 0.25, 0.5, 0.75, 0.95, 1.0)]
    assert all(a <= b for a, b in zip(qs, qs[1:]))
    assert h.count == len(xs)
    np.testing.assert_allclose(h.sum, sum(xs), rtol=1e-9)


# ------------------------------------------------------------ OocStats
def test_oocstats_mapping_surface():
    st_ = OocStats(codec="pq", bytes_read=42)
    assert st_["bytes_read"] == 42 and st_.get("codec") == "pq"
    assert st_.get("nope", 3) == 3
    with pytest.raises(KeyError):
        st_["nope"]
    assert "bytes_read" in st_ and "nope" not in st_
    assert dict(st_.items())["codec"] == "pq"
    assert st_.as_dict()["bytes_read"] == 42


def test_oocstats_aggregate_rates_and_weighted_slack():
    s1 = OocStats(hits=3, misses=1, hits_distinct=2, bytes_read=100,
                  stop_epsilon=2, eps_slack=1.0, stop_delta=1,
                  delta_slack=4.0, pruning_ratio=0.5, iterations=2)
    s2 = OocStats(hits=1, misses=3, hits_distinct=1, bytes_read=50,
                  stop_epsilon=0, eps_slack=99.0,  # zero-weight: ignored
                  pruning_ratio=0.7, iterations=3)
    agg = OocStats.aggregate([s1, s2])
    assert agg.bytes_read == 150 and agg.iterations == 5
    assert agg.hits == 4 and agg.misses == 4
    np.testing.assert_allclose(agg.hit_rate, 4 / 8)
    np.testing.assert_allclose(agg.hit_rate_distinct, 3 / 7)
    np.testing.assert_allclose(agg.eps_slack, 1.0)
    np.testing.assert_allclose(agg.delta_slack, 4.0)
    np.testing.assert_allclose(agg.pruning_ratio, 0.6)
    assert agg.shards == [s1, s2]
    assert OocStats.aggregate([]).bytes_read == 0


# ------------------------------------- no-drift contract on a real query
def test_span_attrs_match_stats_on_real_query(walk_data, walk_queries,
                                              tmp_path, traced):
    ix = dstree.build(walk_data, leaf_cap=32)
    store = FrozenIndex.load(ix.save(str(tmp_path / "idx")),
                             resident="summaries")
    out = S.search_ooc(store, walk_queries, 5, G.epsilon(1.0),
                       cache_leaves=6)
    st_ = out.stats
    prof = obs.last_profile("ooc.query")
    assert prof is not None
    # the span attrs ARE the OocStats fields — one schema, two views
    for field in ("bytes_read", "bytes_h2d", "iterations",
                  "leaves_visited", "rows_scanned", "frontier_refills",
                  "stop_delta", "stop_epsilon", "stop_exhausted"):
        assert prof.attrs[field] == st_[field], field
    assert prof.count("ooc.iteration") == st_.iterations
    assert {"ooc.filter", "ooc.iteration",
            "ooc.finalize"} <= set(prof.phase_ms)
    # every lane accounted to exactly one stop condition
    assert (st_.stop_delta + st_.stop_epsilon
            + st_.stop_exhausted) == walk_queries.shape[0]
    assert 0.0 <= st_.pruning_ratio <= 1.0
    # per-iteration demand reads fold up to the sync-read total
    assert prof.total("bytes_read_sync") == st_.bytes_read_sync


def test_tracing_does_not_change_answers(walk_data, walk_queries,
                                         tmp_path):
    ix = dstree.build(walk_data, leaf_cap=32)
    store = FrozenIndex.load(ix.save(str(tmp_path / "idx")),
                             resident="summaries")
    plain = S.search_ooc(store, walk_queries, 5, G.epsilon(1.0),
                         cache_leaves=6)
    obs.enable()
    try:
        traced = S.search_ooc(store, walk_queries, 5, G.epsilon(1.0),
                              cache_leaves=6)
    finally:
        obs.disable()
        obs.clear()
    np.testing.assert_array_equal(np.asarray(plain.result.ids),
                                  np.asarray(traced.result.ids))
    np.testing.assert_array_equal(np.asarray(plain.result.dists),
                                  np.asarray(traced.result.dists))
    assert plain.stats.leaves_visited == traced.stats.leaves_visited


# ------------------------------------------------- serve-side plumbing
def test_request_submitted_at_on_the_shared_clock():
    from repro.serve.batching import Request

    t0 = obs.now()
    r = Request(uid=0, prompt=np.arange(4, dtype=np.int32))
    t1 = obs.now()
    assert t0 <= r.submitted_at <= t1


def test_run_retrieval_attributes_time_per_group(traced):
    """Satellite: a request is charged its OWN guarantee group's
    retrieval time, not the whole batch's."""
    import jax.numpy as jnp

    from repro.core.search import SearchResult
    from repro.serve.batching import Request, Scheduler

    class SleepyEngine:
        def query(self, q, k, g):
            if g.kind == "ng":
                time.sleep(0.05)  # only the degraded tier is slow
            b = q.shape[0]
            return SearchResult(
                dists=jnp.zeros((b, k), jnp.float32),
                ids=jnp.tile(jnp.arange(k, dtype=jnp.int32), (b, 1)),
                leaves_visited=jnp.zeros((b,), jnp.int32),
                rows_scanned=jnp.zeros((b,), jnp.int32),
                lb_computed=jnp.int32(0),
            )

    reqs = [Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    series=np.zeros(8, np.float32)),
            Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                    deadline_ms=2.0, series=np.zeros(8, np.float32))]
    eng = SleepyEngine()
    Scheduler().run_retrieval(eng, reqs, k=3)  # warm jnp dispatch
    out = Scheduler().run_retrieval(eng, reqs, k=3)
    assert out[1]["kind"] == "ng" and out[0]["kind"] == "exact"
    assert out[1]["retrieval_ms"] >= 50.0
    # the exact-group request is NOT charged for the ng group's sleep
    assert out[0]["retrieval_ms"] < out[1]["retrieval_ms"]
    kinds = {sp.attrs["kind"] for sp in
             traced.find("serve.retrieval_group")}
    assert kinds == {"exact", "ng"}
