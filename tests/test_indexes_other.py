"""IMI / graph (HNSW-style) / SRS behavior tests."""

import jax.numpy as jnp
import pytest

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import graph, imi, srs
from repro.core.metrics import workload_metrics

pytestmark = pytest.mark.tier1

K = 5


@pytest.fixture(scope="module")
def bf(walk_data, walk_queries):
    return S.brute_force(jnp.asarray(walk_queries),
                         jnp.asarray(walk_data), K)


def test_imi_recall_improves_with_nprobe(walk_data, walk_queries, bf):
    idx = imi.build(walk_data, kc=8, m=16, kmeans_iters=10)
    r1 = imi.query(idx, jnp.asarray(walk_queries), K, G.ng(1))
    r2 = imi.query(idx, jnp.asarray(walk_queries), K, G.ng(32))
    m1 = workload_metrics(r1.ids, r1.dists, bf.ids, bf.dists)
    m2 = workload_metrics(r2.ids, r2.dists, bf.ids, bf.dists)
    assert m2["avg_recall"] >= m1["avg_recall"]
    assert m2["avg_recall"] > 0.4


def test_imi_refine_closes_the_map_gap(walk_data, walk_queries, bf):
    """Paper finding C4: ADC-only IMI has MAP below its recall; raw
    re-ranking recovers it."""
    idx = imi.build(walk_data, kc=8, m=16, kmeans_iters=10)
    plain = imi.query(idx, jnp.asarray(walk_queries), K, G.ng(64))
    ref = imi.query(idx, jnp.asarray(walk_queries), K, G.ng(64),
                    refine=True)
    mp = workload_metrics(plain.ids, plain.dists, bf.ids, bf.dists)
    mr = workload_metrics(ref.ids, ref.dists, bf.ids, bf.dists)
    assert mr["map"] >= mp["map"]
    assert mr["mre"] <= mp["mre"] + 1e-6


def test_graph_beam_width_tradeoff(walk_data, walk_queries, bf):
    idx = graph.build(walk_data, m_links=8)
    lo = graph.query(idx, jnp.asarray(walk_queries), K, efs=8)
    hi = graph.query(idx, jnp.asarray(walk_queries), K, efs=128)
    mlo = workload_metrics(lo.ids, lo.dists, bf.ids, bf.dists)
    mhi = workload_metrics(hi.ids, hi.dists, bf.ids, bf.dists)
    assert mhi["avg_recall"] >= mlo["avg_recall"]
    assert mhi["avg_recall"] > 0.6


def test_graph_is_ng_only_interface(walk_data):
    """Graph query takes no guarantee params — Table 1 categorization."""
    import inspect

    sig = inspect.signature(graph.query)
    assert "epsilon" not in sig.parameters
    assert "delta" not in sig.parameters


def test_srs_delta_controls_scan_depth(walk_data, walk_queries, bf):
    idx = srs.build(walk_data, m=16)
    loose = srs.query(idx, jnp.asarray(walk_queries), K,
                      G.delta_epsilon(0.5, 1.0))
    tight = srs.query(idx, jnp.asarray(walk_queries), K,
                      G.delta_epsilon(0.99, 0.0))
    assert int(loose.rows_scanned.sum()) <= int(tight.rows_scanned.sum())
    m = workload_metrics(tight.ids, tight.dists, bf.ids, bf.dists)
    assert m["avg_recall"] > 0.8


def test_srs_tiny_index_footprint(walk_data):
    """SRS's selling point: index (projections) is m/n of the data."""
    idx = srs.build(walk_data, m=8)
    feat_bytes = idx.feats.size * 4
    data_bytes = idx.data.size * 4
    assert feat_bytes <= data_bytes * 8 / walk_data.shape[1] + 1024
