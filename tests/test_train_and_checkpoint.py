"""Training loop, grad accumulation, compression, checkpoint/restart,
fault injection (bitwise replay)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import tokens as tokens_mod
from repro.models import model as M
from repro.models.params import initialize
from repro.train import compress, optimizer as opt_mod
from repro.train.checkpoint import Checkpointer
from repro.train.fault import FaultInjector, Supervisor
from repro.train.train_step import build_train_step

KEY = jax.random.PRNGKey(0)


def setup(arch="llama3-405b", lr=3e-3):
    cfg = get_smoke_config(arch)
    params = initialize(M.model_specs(cfg), KEY)
    ocfg = opt_mod.OptConfig(lr=lr, warmup_steps=5, total_steps=100)
    opt_state = opt_mod.init(ocfg, params)
    return cfg, ocfg, params, opt_state


def make_batch_fn(cfg, batch=4, seq=32, seed=0):
    def f(step):
        return tokens_mod.batch_at_step(seed, step, batch, seq,
                                        cfg.vocab_size)
    return f


def test_loss_decreases():
    cfg, ocfg, params, opt_state = setup()
    step = jax.jit(build_train_step(cfg, ocfg))
    mk = make_batch_fn(cfg)
    losses = []
    for i in range(30):
        params, opt_state, m = step(params, opt_state, mk(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_grad_accum_matches_full_batch():
    cfg, ocfg, params, opt_state = setup()
    batch = make_batch_fn(cfg, batch=8)(0)
    s1 = build_train_step(cfg, ocfg, grad_accum=1)
    s2 = build_train_step(cfg, ocfg, grad_accum=4)
    p1, _, m1 = jax.jit(s1)(params, opt_state, batch)
    p2, _, m2 = jax.jit(s2)(params, opt_state, batch)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-3, rtol=5e-3)


def test_compressed_training_still_converges():
    cfg, ocfg, params, opt_state = setup(lr=3e-3)
    step = jax.jit(build_train_step(cfg, ocfg, compression=True))
    err = compress.init_error_state(params)
    mk = make_batch_fn(cfg)
    losses = []
    for i in range(30):
        params, opt_state, err, m = step(params, opt_state, mk(i), err)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_ef_quantize_reduces_bias():
    """Error feedback: accumulated quantized updates track the true sum
    far better than naive quantization."""
    rng = np.random.default_rng(0)
    g = [jnp.asarray(rng.normal(size=(64,)) * 10 ** rng.uniform(-3, 0),
                     jnp.float32) for _ in range(50)]
    err = jnp.zeros((64,))
    acc_ef = jnp.zeros((64,))
    acc_naive = jnp.zeros((64,))
    for gi in g:
        dq, err = compress.ef_quantize(gi, err)
        acc_ef = acc_ef + dq
        dq_n, _ = compress.ef_quantize(gi, jnp.zeros((64,)))
        acc_naive = acc_naive + dq_n
    true = sum(g)
    assert float(jnp.abs(acc_ef - true).max()) <= \
        float(jnp.abs(acc_naive - true).max()) + 1e-5


def test_checkpoint_roundtrip(tmp_path):
    cfg, ocfg, params, opt_state = setup()
    ck = Checkpointer(str(tmp_path))
    ck.save(7, {"params": params, "opt_state": opt_state},
            extra={"note": "x"}, sync=True)
    step, state, extra = ck.restore(
        {"params": params, "opt_state": opt_state})
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    cfg, ocfg, params, opt_state = setup()
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params}, sync=True)
    target = os.path.join(str(tmp_path), "step_00000001", "params.npz")
    with open(target, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 32)
    with pytest.raises(IOError, match="corruption"):
        ck.restore({"params": params})


def test_checkpoint_retention(tmp_path):
    cfg, ocfg, params, _ = setup()
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"params": params}, sync=True)
    assert ck.all_steps() == [3, 4]


def test_fault_injection_replays_bitwise(tmp_path):
    """Kill at step 12, restart from checkpoint 10: the loss stream must
    equal an uninterrupted run (stateless data + deterministic step)."""
    cfg, ocfg, params0, opt0 = setup()
    step = jax.jit(build_train_step(cfg, ocfg))
    mk = make_batch_fn(cfg)

    sup = Supervisor(step, mk, Checkpointer(str(tmp_path / "a")),
                     ckpt_every=5,
                     injector=FaultInjector(fail_at=[12]))
    out_faulty = sup.run(params0, opt0, 0, 20)
    assert out_faulty["restarts"] == 1

    cfg2, ocfg2, params1, opt1 = setup()
    sup2 = Supervisor(step, mk, Checkpointer(str(tmp_path / "b")),
                      ckpt_every=5)
    out_clean = sup2.run(params1, opt1, 0, 20)
    np.testing.assert_allclose(out_faulty["losses"],
                               out_clean["losses"], atol=0, rtol=0)


def test_elastic_restore_via_fit(tmp_path):
    """fit() resumes from the latest checkpoint (cursor + state)."""
    from repro.launch.train import fit

    cfg = get_smoke_config("minitron-8b")
    fit(cfg, steps=10, batch=2, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    out2 = fit(cfg, steps=14, batch=2, seq=16,
               ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    assert out2["final_step"] == 14
    assert len(out2["losses"]) == 4  # resumed at 10
