"""Optional-hypothesis shim for the property-based test modules.

When `hypothesis` is installed, re-exports the real `given`, `settings`,
`strategies` and `hypothesis.extra.numpy`, so nothing changes. When it is
missing (offline containers), provides a deterministic fallback: each
strategy can draw concrete examples from a seeded Generator, and `given`
re-runs the test body over a fixed sweep of draws. Coverage is thinner
than real hypothesis but the invariants are still exercised, and — most
importantly — collection no longer hard-errors the whole suite.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is present
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        """Subset of hypothesis.strategies used by this repo's tests."""

        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi, width=64, allow_nan=False,
                   allow_infinity=False):
            dt = np.float32 if width == 32 else np.float64
            return _Strategy(
                lambda rng: dt(rng.uniform(lo, hi)))

        @staticmethod
        def lists(elems, min_size=0, max_size=10, unique=False):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                out, seen = [], set()
                # bounded rejection sampling for `unique`
                for _ in range(1000):
                    if len(out) == size:
                        break
                    v = elems.draw(rng)
                    if unique:
                        if v in seen:
                            continue
                        seen.add(v)
                    out.append(v)
                return out

            return _Strategy(draw)

    class _Hnp:
        @staticmethod
        def arrays(dtype, shape, elements=None):
            def draw(rng):
                if elements is None:
                    flat = rng.standard_normal(int(np.prod(shape)))
                else:
                    flat = np.asarray(
                        [elements.draw(rng)
                         for _ in range(int(np.prod(shape)))])
                return flat.reshape(shape).astype(dtype)

            return _Strategy(draw)

    def given(*strategies, **kw_strategies):
        assert not kw_strategies, "shim supports positional strategies"

        def deco(fn):
            # Deliberately NOT functools.wraps: the wrapper must expose a
            # zero-arg signature or pytest treats the drawn parameters as
            # fixtures.
            def run():
                for ex in range(_FALLBACK_EXAMPLES):
                    rng = np.random.default_rng(ex)
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*drawn)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run

        return deco

    def settings(**_kw):  # deadline/max_examples are no-ops here
        def deco(fn):
            return fn

        return deco

    st = _St()
    hnp = _Hnp()

__all__ = ["given", "settings", "st", "hnp", "HAVE_HYPOTHESIS"]
