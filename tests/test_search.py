"""Search correctness: Algorithm 1/2 semantics per index, guarantee
properties (the paper's taxonomy, property-tested), counters."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.guarantees import Guarantee, delta_epsilon, epsilon, exact, ng
from repro.core.indexes import dstree, isax, vafile
from repro.core.metrics import workload_metrics

pytestmark = pytest.mark.tier1

K = 5


@pytest.fixture(scope="module", params=["isax", "dstree", "vafile"])
def built(request, walk_data):
    builders = {
        "isax": lambda d: isax.build(d, leaf_cap=32),
        "dstree": lambda d: dstree.build(d, leaf_cap=32),
        "vafile": lambda d: vafile.build(d),
    }
    vb = {"isax": 1, "dstree": 1, "vafile": 32}
    return (request.param, builders[request.param](walk_data),
            vb[request.param])


@pytest.fixture(scope="module")
def bf(walk_data, walk_queries):
    return S.brute_force(jnp.asarray(walk_queries),
                         jnp.asarray(walk_data), K)


def test_exact_matches_brute_force(built, walk_queries, bf):
    name, idx, vb = built
    res = S.search(idx, jnp.asarray(walk_queries), K, visit_batch=vb)
    np.testing.assert_allclose(res.dists, bf.dists, rtol=1e-3, atol=1e-3)
    m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
    assert m["map"] == pytest.approx(1.0)
    assert m["mre"] < 1e-3


@pytest.mark.parametrize("eps", [0.1, 0.5, 2.0])
def test_epsilon_guarantee_holds(built, walk_queries, bf, eps):
    """Deterministic (1+eps) bound vs exact distances — Definition 5."""
    name, idx, vb = built
    res = S.search(idx, jnp.asarray(walk_queries), K, G.epsilon(eps),
                   visit_batch=vb)
    assert bool((res.dists <= (1 + eps) * bf.dists * (1 + 1e-4)
                 + 1e-4).all())


def test_epsilon_prunes_more_than_exact(built, walk_queries):
    name, idx, vb = built
    ex = S.search(idx, jnp.asarray(walk_queries), K, visit_batch=vb)
    ap = S.search(idx, jnp.asarray(walk_queries), K, G.epsilon(2.0),
                  visit_batch=vb)
    assert int(ap.leaves_visited.sum()) <= int(ex.leaves_visited.sum())
    assert int(ap.rows_scanned.sum()) <= int(ex.rows_scanned.sum())


def test_delta_one_equals_epsilon_path(built, walk_queries):
    """delta=1 must reduce delta-epsilon to plain epsilon (taxonomy)."""
    name, idx, vb = built
    a = S.search(idx, jnp.asarray(walk_queries), K, G.epsilon(0.5),
                 visit_batch=vb)
    b = S.search(idx, jnp.asarray(walk_queries), K,
                 G.delta_epsilon(1.0, 0.5), visit_batch=vb)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_allclose(a.dists, b.dists, atol=0)


def test_delta_epsilon_is_at_least_as_fast(built, walk_queries):
    name, idx, vb = built
    e = S.search(idx, jnp.asarray(walk_queries), K, G.epsilon(0.5),
                 visit_batch=vb)
    de = S.search(idx, jnp.asarray(walk_queries), K,
                  G.delta_epsilon(0.9, 0.5), visit_batch=vb)
    assert int(de.leaves_visited.sum()) <= int(e.leaves_visited.sum())


def test_ng_respects_nprobe(built, walk_queries):
    name, idx, vb = built
    res = S.search(idx, jnp.asarray(walk_queries), K, G.ng(3),
                   visit_batch=vb)
    # batched visits may overshoot by < visit_batch, never more
    assert int(res.leaves_visited.max()) <= 3
    res2 = S.search(idx, jnp.asarray(walk_queries), K, G.ng(1),
                    visit_batch=vb)
    assert int(res2.leaves_visited.max()) <= 1
    # first-leaf bsf is a valid answer; a 1-series leaf (VA+file) fills
    # only the first slot — the paper's "visit one leaf" baseline
    assert bool(jnp.isfinite(res2.dists[:, 0]).all())


def test_visit_batch_does_not_change_exactness(built, walk_queries, bf):
    name, idx, vb = built
    res = S.search(idx, jnp.asarray(walk_queries), K, visit_batch=8)
    np.testing.assert_allclose(res.dists, bf.dists, rtol=1e-3, atol=1e-3)


def test_counters_monotone_in_accuracy(built, walk_queries):
    name, idx, vb = built
    probes = [1, 4, 16]
    leaves = []
    for p in probes:
        r = S.search(idx, jnp.asarray(walk_queries), K, G.ng(p),
                     visit_batch=vb)
        leaves.append(int(r.leaves_visited.sum()))
    assert leaves == sorted(leaves)


def test_guarantee_kinds():
    assert exact().kind == "exact"
    assert epsilon(0.5).kind == "epsilon"
    assert delta_epsilon(0.9, 0.1).kind == "delta-epsilon"
    assert ng(4).kind == "ng"
    assert Guarantee(delta=1.0, epsilon=0.0).kind == "exact"
    with pytest.raises(ValueError):
        Guarantee(delta=1.5).validate()
    with pytest.raises(ValueError):
        Guarantee(epsilon=-1.0).validate()
