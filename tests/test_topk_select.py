"""PR 3 partial-selection hot path: the selection-based merges must
agree EXACTLY (ids and distances, ties included) with the retained
full-sort oracles, the fused score+select kernel with its jnp oracle,
and the lazy leaf-frontier must emit the stable-argsort visit order on
adversarial lower-bound distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import dstree, vafile
from repro.kernels import ops, ref
from repro.store.ooc import _frontier_refill

pytestmark = pytest.mark.tier1

RNG = np.random.default_rng(7)
INF = np.float32(np.inf)


def _assert_pair_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def _running_top(b, k, n_real, id_base=10**6):
    """A legal running top-k: sorted distances, distinct ids, the last
    k - n_real slots the (inf, -1) placeholder."""
    d = np.sort(RNG.uniform(0.2, 0.8, (b, k)).astype(np.float32), axis=1)
    i = id_base + np.arange(b * k).reshape(b, k)
    d[:, n_real:] = INF
    i = np.where(np.arange(k)[None, :] < n_real, i, -1)
    return jnp.asarray(d), jnp.asarray(i, jnp.int32)


def _candidates(b, m, tie_frac=0.0, invalid_frac=0.0, dup_running=None,
                id_base=0):
    """Candidate block honoring the call-site invariant: distinct real
    ids per row (-1 placeholders repeat, paired with inf)."""
    d = RNG.uniform(0.0, 1.0, (b, m)).astype(np.float32)
    if tie_frac:
        # quantize to force many exact distance ties
        d = np.round(d * 8).astype(np.float32) / 8
    ids = np.stack([RNG.permutation(4 * m)[:m] for _ in range(b)]) \
        + id_base
    if invalid_frac:
        mask = RNG.uniform(size=(b, m)) < invalid_frac
        d = np.where(mask, INF, d)
        ids = np.where(mask, -1, ids)
    if dup_running is not None:
        # overwrite a few columns with ids already in the running top
        # (the cross-iteration duplicate-leaf case), equal distances
        top_d, top_i = dup_running
        k = np.asarray(top_i).shape[1]
        take = min(k, m) // 2 or 1
        cols = RNG.choice(m, take, replace=False)
        rows_i = np.asarray(top_i)[:, :take]
        rows_d = np.asarray(top_d)[:, :take]
        usable = rows_i >= 0
        ids[:, cols] = np.where(usable, rows_i, ids[:, cols])
        d[:, cols] = np.where(usable, rows_d, d[:, cols])
    return jnp.asarray(d), jnp.asarray(ids, jnp.int32)


# ------------------------------------------------------ topk_merge


@pytest.mark.parametrize("m", [3, 7, 64, 300])  # includes widths < k
@pytest.mark.parametrize("tie_frac,invalid_frac",
                         [(0.0, 0.0), (1.0, 0.0), (0.0, 0.4),
                          (1.0, 0.3)])
def test_topk_merge_exact_vs_full_sort_oracle(m, tie_frac, invalid_frac):
    k = 10
    top = _running_top(4, k, n_real=6)
    d, ids = _candidates(4, m, tie_frac, invalid_frac)
    _assert_pair_equal(ops.topk_merge(d, ids, *top),
                       ref.ref_topk_merge(d, ids, *top))


def test_topk_merge_tie_order_matches_stable_sort():
    """Distance ties must resolve exactly as the stable full sort:
    running entries first, then candidates by column position."""
    d = jnp.asarray([[0.5, 0.5, 0.25, 0.5]], jnp.float32)
    ids = jnp.asarray([[11, 12, 13, 14]], jnp.int32)
    top_d = jnp.asarray([[0.5, 0.5, jnp.inf]], jnp.float32)
    top_i = jnp.asarray([[7, 8, -1]], jnp.int32)
    got = ops.topk_merge(d, ids, top_d, top_i)
    want = ref.ref_topk_merge(d, ids, top_d, top_i)
    _assert_pair_equal(got, want)
    assert np.asarray(got[1]).tolist() == [[13, 7, 8]]


def test_bitonic_merge_is_the_stable_merge():
    for ka, kb in [(1, 1), (5, 5), (8, 3), (10, 20)]:
        da = jnp.sort(jnp.asarray(
            np.round(RNG.uniform(size=(3, ka)) * 4) / 4, jnp.float32), 1)
        db = jnp.sort(jnp.asarray(
            np.round(RNG.uniform(size=(3, kb)) * 4) / 4, jnp.float32), 1)
        ia = jnp.asarray(RNG.integers(0, 99, (3, ka)), jnp.int32)
        ib = jnp.asarray(RNG.integers(100, 199, (3, kb)), jnp.int32)
        md, mi = ops.bitonic_merge_sorted(da, ia, db, ib)
        wd, wi = jax.lax.sort(
            (jnp.concatenate([da, db], 1),
             jnp.concatenate([ia, ib], 1)), num_keys=1)
        _assert_pair_equal((md, mi), (wd, wi))


# ----------------------------------------------- topk_merge_unique


@pytest.mark.parametrize("m", [3, 7, 64, 500])
@pytest.mark.parametrize("tie_frac,invalid_frac,dup",
                         [(0.0, 0.0, False), (1.0, 0.0, False),
                          (0.0, 0.5, False), (0.0, 0.0, True),
                          (1.0, 0.3, True)])
def test_topk_merge_unique_exact_vs_full_sort_oracle(
        m, tie_frac, invalid_frac, dup):
    k = 10
    top = _running_top(4, k, n_real=7)
    d, ids = _candidates(4, m, tie_frac, invalid_frac,
                         dup_running=top if dup else None)
    _assert_pair_equal(ops.topk_merge_unique(d, ids, *top),
                       ref.ref_topk_merge_unique(d, ids, *top))


def test_topk_merge_unique_shared_ids_path_exact():
    """The 1-D (lane-invariant pool) fast path must equal both the 2-D
    path and the oracle — including duplicate ids across the
    k-boundary (same id on both sides of the selection cut)."""
    k = 5
    b, m = 3, 40
    top_d, top_i = _running_top(b, k, n_real=5, id_base=0)
    ids1 = jnp.asarray(RNG.permutation(m), jnp.int32)
    d = np.round(RNG.uniform(size=(b, m)) * 6).astype(np.float32) / 6
    # lane 0: place a running id among candidates at its running
    # distance (cross-iteration duplicate)
    ids1_np = np.asarray(ids1)
    d[0, ids1_np == ids1_np[0]] = float(np.asarray(top_d)[0, 0])
    d = jnp.asarray(d)
    ids2 = jnp.broadcast_to(ids1[None], (b, m))
    want = ref.ref_topk_merge_unique(d, ids2, top_d, top_i)
    _assert_pair_equal(ops.topk_merge_unique(d, ids1, top_d, top_i),
                       want)
    _assert_pair_equal(ops.topk_merge_unique(d, ids2, top_d, top_i),
                       want)


def test_topk_merge_unique_all_invalid_candidates():
    k = 4
    top = _running_top(2, k, n_real=2)
    d = jnp.full((2, 9), jnp.inf)
    ids = jnp.full((2, 9), -1, jnp.int32)
    _assert_pair_equal(ops.topk_merge_unique(d, ids, *top),
                       ref.ref_topk_merge_unique(d, ids, *top))


def test_topk_merge_unique_keeps_distinct_ids_invariant():
    k = 6
    top = _running_top(3, k, n_real=4)
    for _ in range(5):
        d, ids = _candidates(3, 64, tie_frac=1.0, invalid_frac=0.2,
                             dup_running=top)
        top = ops.topk_merge_unique(d, ids, *top)
        for row in np.asarray(top[1]):
            real = row[row >= 0]
            assert len(np.unique(real)) == len(real)


# ------------------------------------- fused cooperative score+select


@pytest.mark.parametrize("b,r,n,kk", [(1, 32, 16, 3), (5, 96, 64, 9),
                                      (8, 256, 100, 20)])
def test_coop_score_select_jnp_matches_oracle(b, r, n, kk):
    q = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
    rows = jnp.asarray(RNG.normal(size=(r, n)), jnp.float32)
    rn = ops.row_sq_norms(rows)
    ids = jnp.asarray(
        np.where(RNG.uniform(size=r) < 0.25, -1, np.arange(r)),
        jnp.int32)
    od, oi = ref.ref_coop_score_select(q, rows, rn, ids, kk)
    jd, ji = ops.coop_score_select(q, rows, rn, ids, kk)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(ji))
    np.testing.assert_allclose(np.asarray(od), np.asarray(jd),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,r,n,kk,dtype",
                         [(5, 96, 64, 9, jnp.float32),
                          (3, 64, 32, 6, jnp.bfloat16)])
def test_coop_score_select_pallas_matches_oracle(b, r, n, kk, dtype):
    """Interpret-mode validation of the fused kernel (kernels/topk.py):
    the [B, R] distance matrix never leaves VMEM on TPU, yet the
    selected (d, id) pairs match the jnp oracle."""
    q = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
    rows = jnp.asarray(RNG.normal(size=(r, n)), dtype)
    rn = ops.row_sq_norms(rows)
    ids = jnp.asarray(
        np.where(RNG.uniform(size=r) < 0.25, -1, np.arange(r)),
        jnp.int32)
    od, oi = ref.ref_coop_score_select(q, rows, rn, ids, kk)
    pd, pi = ops.coop_score_select(q, rows, rn, ids, kk,
                                   force_pallas=True, tile_b=8,
                                   tile_r=32)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(pi))
    tol = 2e-1 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(od), np.asarray(pd), atol=tol,
                               rtol=tol)


def test_coop_score_select_pallas_pads_to_tiles():
    """Non-tile-multiple B and R must pad without contaminating real
    lanes (padding ids are -1 -> masked to inf)."""
    b, r, n, kk = 5, 70, 48, 7
    q = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32)
    rows = jnp.asarray(RNG.normal(size=(r, n)), jnp.float32)
    rn = ops.row_sq_norms(rows)
    ids = jnp.asarray(np.arange(r), jnp.int32)
    od, oi = ref.ref_coop_score_select(q, rows, rn, ids, kk)
    pd, pi = ops.coop_score_select(q, rows, rn, ids, kk,
                                   force_pallas=True, tile_b=8,
                                   tile_r=32)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(pi))
    np.testing.assert_allclose(np.asarray(od), np.asarray(pd),
                               atol=1e-3)


# ---------------------------------------- fused PQ-ADC score+select


def _pq_pool(b, r, m=8, K=16, masked_frac=0.25):
    codes = jnp.asarray(RNG.integers(0, K, size=(r, m)), jnp.int32)
    luts = jnp.asarray(RNG.uniform(size=(b, m, K)), jnp.float32)
    ids = jnp.asarray(
        np.where(RNG.uniform(size=r) < masked_frac, -1, np.arange(r)),
        jnp.int32)
    return codes, luts, ids


@pytest.mark.parametrize("b,r,kk", [(1, 32, 3), (5, 96, 9),
                                    (8, 256, 20)])
def test_pq_adc_select_jnp_matches_oracle(b, r, kk):
    codes, luts, ids = _pq_pool(b, r)
    od, oi = ref.ref_pq_adc_select(codes, luts, ids, kk)
    jd, ji = ops.pq_adc_select(codes, luts, ids, kk)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(ji))
    np.testing.assert_allclose(np.asarray(od), np.asarray(jd),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,r,m,K,kk", [(5, 96, 8, 16, 9),
                                        (8, 128, 16, 32, 12)])
def test_pq_adc_select_pallas_matches_oracle(b, r, m, K, kk):
    """Interpret-mode validation of the fused PQ kernel
    (kernels/pq_adc_select.py): codes stream through the one-hot MXU
    contraction tile by tile, the [B, R] ADC matrix never leaves VMEM
    on TPU, yet the selected (d, id) pairs match the
    full-materialization jnp oracle."""
    codes, luts, ids = _pq_pool(b, r, m=m, K=K)
    od, oi = ref.ref_pq_adc_select(codes, luts, ids, kk)
    pd, pi = ops.pq_adc_select(codes, luts, ids, kk,
                               force_pallas=True, tile_b=8, tile_r=32)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(pi))
    np.testing.assert_allclose(np.asarray(od), np.asarray(pd),
                               atol=1e-4, rtol=1e-4)


def test_pq_adc_select_pallas_pads_to_tiles():
    """Non-tile-multiple B and R must pad without contaminating real
    lanes (padding ids are -1 -> masked to inf; padded lanes are
    sliced off)."""
    b, r, kk = 5, 70, 7
    codes, luts, _ = _pq_pool(b, r, masked_frac=0.0)
    ids = jnp.asarray(np.arange(r), jnp.int32)
    od, oi = ref.ref_pq_adc_select(codes, luts, ids, kk)
    pd, pi = ops.pq_adc_select(codes, luts, ids, kk,
                               force_pallas=True, tile_b=8, tile_r=32)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(pi))
    np.testing.assert_allclose(np.asarray(od), np.asarray(pd),
                               atol=1e-4)


def test_pq_adc_select_adversarial_ties():
    """Duplicated code rows under distinct ids produce EXACTLY tied
    ADC distances (identical summands in both formulations); the
    kernel's tie-break must come out id-ascending, matching the
    oracle's lex sort, across every tile boundary."""
    b, r, kk = 4, 96, 16
    base, luts, _ = _pq_pool(b, 8, masked_frac=0.0)
    codes = jnp.asarray(
        np.tile(np.asarray(base), (r // 8, 1)), jnp.int32)  # 12x dups
    ids = jnp.asarray(np.arange(r), jnp.int32)
    od, oi = ref.ref_pq_adc_select(codes, luts, ids, kk)
    jd, ji = ops.pq_adc_select(codes, luts, ids, kk)
    pd, pi = ops.pq_adc_select(codes, luts, ids, kk,
                               force_pallas=True, tile_b=4, tile_r=16)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(ji))
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(pi))
    np.testing.assert_allclose(np.asarray(od), np.asarray(pd),
                               atol=1e-4)
    # every distance is a 12-way tie run -> the emitted order must be
    # (d, id)-lexicographic: ids strictly ascend wherever d ties
    for lane in range(b):
        dl, il = np.asarray(pd[lane]), np.asarray(pi[lane])
        tied = dl[1:] == dl[:-1]
        assert tied.any()  # the construction really does tie
        assert (il[1:][tied] > il[:-1][tied]).all()


def test_pq_adc_select_matches_pre_fusion_corner():
    """selection + dedup_merge_topk == the pre-fusion cooperative pq
    corner (full pq_adc_batch matrix + topk_merge_unique), bit-exact
    on CPU — ids AND distances, placeholders included."""
    b, r, k = 6, 128, 10
    codes, luts, ids = _pq_pool(b, r)
    top_d = jnp.full((b, k), jnp.inf)
    top_i = jnp.full((b, k), -1, jnp.int32)
    d = ref.ref_pq_adc_batch(codes, luts)
    d = jnp.where(ids[None, :] < 0, INF, d)
    want = ops.topk_merge_unique(d, ids, top_d, top_i)
    sel_d, sel_i = ops.pq_adc_select(codes, luts, ids,
                                     min(2 * k, r))
    got = ops.dedup_merge_topk(sel_d, sel_i, top_d, top_i)
    _assert_pair_equal(got, want)


# ------------------------------------------------ lazy leaf frontier


def _adversarial_lb(b, L):
    """Heavily tied lower bounds: a handful of distinct values, long
    runs of exact duplicates (the frontier's worst case: ties straddle
    every refill boundary)."""
    vals = np.asarray([0.0, 0.0, 1.0, 1.0, 1.0, 2.5], np.float32)
    return RNG.choice(vals, size=(b, L)).astype(np.float32)


@pytest.mark.parametrize("f", [2, 3, 7, 16])
def test_frontier_refill_emits_stable_argsort_order(f):
    """Chaining refills window by window must reproduce the FULL stable
    argsort order exactly, for any frontier width."""
    b, L = 4, 37
    lb = _adversarial_lb(b, L)
    lb_d = jnp.asarray(lb)
    thr_lb = np.full(b, -1.0, np.float32)
    thr_id = np.full(b, -1, np.int64)
    emitted = []
    steps = 0
    while steps * f < L + f:
        w_lb, w_id = _frontier_refill(
            lb_d, jnp.asarray(thr_lb), jnp.asarray(thr_id, jnp.int32), f)
        w_lb, w_id = np.asarray(w_lb), np.asarray(w_id)
        emitted.append(w_id)
        thr_lb = w_lb[:, -1]
        thr_id = w_id[:, -1].astype(np.int64)
        steps += 1
    order = np.concatenate(emitted, axis=1)[:, :L]
    want = np.argsort(lb, axis=1, kind="stable")
    np.testing.assert_array_equal(order, want)
    # and the lbs come out globally non-decreasing (Algorithm 2's
    # correctness condition)
    lb_seq = np.take_along_axis(lb, order, axis=1)
    assert (np.diff(lb_seq, axis=1) >= 0).all()


def test_search_results_invariant_to_frontier_width(walk_data,
                                                    walk_queries):
    """Any frontier width must yield identical results AND identical
    visit counters — the window is an execution detail, not an
    algorithm change."""
    idx = dstree.build(walk_data, leaf_cap=32)
    q = jnp.asarray(walk_queries)
    base = S.search(idx, q, 5, frontier=idx.num_leaves)  # full window
    for f in (2, 5, idx.num_leaves // 2):
        got = S.search(idx, q, 5, frontier=f)
        np.testing.assert_array_equal(np.asarray(base.ids),
                                      np.asarray(got.ids))
        np.testing.assert_array_equal(np.asarray(base.dists),
                                      np.asarray(got.dists))
        np.testing.assert_array_equal(np.asarray(base.leaves_visited),
                                      np.asarray(got.leaves_visited))
        np.testing.assert_array_equal(np.asarray(base.rows_scanned),
                                      np.asarray(got.rows_scanned))


def test_search_frontier_handles_tied_lower_bounds(walk_data,
                                                   walk_queries):
    """VA+file yields massively tied lbs (cell bounds); visit_batch
    forces refills mid-tie-run. Exactness must survive."""
    va = vafile.build(walk_data)
    q = jnp.asarray(walk_queries)
    bf = S.brute_force(q, jnp.asarray(walk_data), 5)
    res = S.search(va, q, 5, visit_batch=64, frontier=70)
    np.testing.assert_allclose(np.asarray(res.dists),
                               np.asarray(bf.dists), rtol=1e-3,
                               atol=1e-3)


def test_ooc_frontier_width_parity(walk_data, walk_queries, tmp_path):
    """search_ooc with a tiny frontier must stay bit-exact to the
    in-memory search (which uses its own default width)."""
    idx = dstree.build(walk_data, leaf_cap=32)
    q = jnp.asarray(walk_queries)
    store_dir = idx.save(str(tmp_path / "idx"))
    from repro.core.index import FrozenIndex
    store = FrozenIndex.load(store_dir, resident="summaries")
    ref_res = S.search(idx, q, 5, G.epsilon(0.5))
    ooc = S.search_ooc(store, q, 5, G.epsilon(0.5), cache_leaves=6,
                       frontier=3)
    np.testing.assert_array_equal(np.asarray(ref_res.ids),
                                  np.asarray(ooc.result.ids))
    np.testing.assert_array_equal(np.asarray(ref_res.dists),
                                  np.asarray(ooc.result.dists))
    np.testing.assert_array_equal(np.asarray(ref_res.leaves_visited),
                                  np.asarray(ooc.result.leaves_visited))


# ------------------------------------------------- cached row norms


def test_row_norms_cached_at_freeze_and_in_sidecar(walk_data, tmp_path):
    idx = dstree.build(walk_data, leaf_cap=32)
    assert idx.row_norms is not None
    np.testing.assert_array_equal(
        np.asarray(idx.row_norms),
        np.asarray(ops.row_sq_norms(idx.data)))
    from repro.core.index import FrozenIndex
    d = idx.save(str(tmp_path / "idx"))
    full = FrozenIndex.load(d)
    np.testing.assert_array_equal(np.asarray(full.row_norms),
                                  np.asarray(idx.row_norms))
    store = FrozenIndex.load(d, resident="summaries")
    np.testing.assert_array_equal(np.asarray(store.resident.row_norms),
                                  np.asarray(idx.row_norms))


def test_row_norms_bf16_sidecar_matches_decoded_payload(walk_data,
                                                        tmp_path):
    """bf16 codec: the cached norms are the norms of the DECODED
    (bfloat16) image, not of the f32 originals — anything else would
    break bit-exact parity with in-memory search over the reloaded
    index."""
    idx = dstree.build(walk_data, leaf_cap=32)
    from repro.core.index import FrozenIndex
    d = idx.save(str(tmp_path / "bf16"), codec="bf16")
    full = FrozenIndex.load(d)
    np.testing.assert_array_equal(
        np.asarray(full.row_norms),
        np.asarray(ops.row_sq_norms(full.data)))


def test_row_norms_absent_in_sidecar_recomputed(walk_data, walk_queries,
                                                tmp_path):
    """Pre-PR3 sidecars have no row_norms key: the open-time fallback
    must recompute them bit-identically and search parity must hold."""
    import os
    idx = dstree.build(walk_data, leaf_cap=32)
    d = idx.save(str(tmp_path / "old"))
    side_path = os.path.join(d, "sidecar.npz")
    side = dict(np.load(side_path))
    side.pop("row_norms")
    np.savez(side_path, **side)
    from repro.core.index import FrozenIndex
    store = FrozenIndex.load(d, resident="summaries")
    np.testing.assert_array_equal(np.asarray(store.resident.row_norms),
                                  np.asarray(idx.row_norms))
    q = jnp.asarray(walk_queries)
    ref_res = S.search(idx, q, 5)
    ooc = S.search_ooc(store, q, 5, cache_leaves=6)
    np.testing.assert_array_equal(np.asarray(ref_res.ids),
                                  np.asarray(ooc.result.ids))
