"""SSD correctness: chunked algorithm vs sequential oracle, decode step
vs full forward, conv causality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

KEY = jax.random.PRNGKey(0)


def rand_inputs(b=2, s=32, h=4, p=8, n=16):
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    bh = jax.random.normal(ks[1], (b, s, h, n)) * 0.5
    ch = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    return xh, bh, ch, dt, a


@pytest.mark.parametrize("chunk", [1, 4, 8, 32])
def test_chunked_matches_sequential(chunk):
    xh, bh, ch, dt, a = rand_inputs()
    y_ref, h_ref = ssm.ssd_reference(xh, bh, ch, dt, a)
    y, h = ssm.ssd_chunked(xh, bh, ch, dt, a, chunk)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(h, h_ref, atol=1e-3, rtol=1e-3)


def test_initial_state_carries():
    xh, bh, ch, dt, a = rand_inputs(s=16)
    h0 = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 16, 8))
    y_ref, _ = ssm.ssd_reference(xh, bh, ch, dt, a, h0=h0)
    y, _ = ssm.ssd_chunked(xh, bh, ch, dt, a, 4, h0=h0)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-3)


def test_layer_decode_matches_prefill():
    """One decode step after prefill == full forward's last position."""
    cfg = ssm.SSMConfig(d_model=32, d_state=16, d_conv=4, expand=2,
                        head_dim=8, n_groups=1, chunk=8)
    from repro.models.params import initialize

    params = initialize(ssm.ssm_specs(cfg, jnp.float32), KEY)
    u = jax.random.normal(KEY, (2, 17, 32))
    full = ssm.ssm_apply(params, u, cfg)
    out_pre, cache = ssm.ssm_apply(params, u[:, :16], cfg,
                                   return_cache=True)
    step_out, _ = ssm.ssm_decode_step(params, u[:, 16:17], cache, cfg)
    np.testing.assert_allclose(
        np.asarray(step_out[:, 0]), np.asarray(full[:, 16]),
        atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(out_pre),
                               np.asarray(full[:, :16]),
                               atol=2e-3, rtol=2e-3)


def test_causal_conv_is_causal():
    x = jnp.zeros((1, 8, 3)).at[0, 4, :].set(1.0)
    k = jnp.ones((4, 3))
    y = ssm._causal_conv(x, k)
    assert float(jnp.abs(y[0, :4]).sum()) == 0.0  # nothing before t=4
    assert float(jnp.abs(y[0, 4:]).sum()) > 0.0


def test_state_is_constant_memory():
    """Decode cache size is independent of sequence length — the
    long_500k enabler."""
    cfg = ssm.SSMConfig(d_model=32, d_state=16, head_dim=8)
    shapes = ssm.ssm_cache_shape(cfg, batch=3)
    total = sum(np.prod(s) for s in shapes.values())
    assert total < 3 * 64 * 16 * 64  # small, seq-independent
