"""Mutation edge cases for the LSM-style delta tier (docs/INGEST.md).

Everything here is held to the tentpole's acceptance bar: frozen+delta
serving must be BIT-exact — ids AND distances — against a from-scratch
rebuild of an engine holding the same live rows, across codecs and the
guarantee taxonomy, before and after compaction. The rebuild oracle is
an actual second DistributedEngine (not brute force: association order
differs there), with its array-order ids remapped to global ids; live
ids are kept ascending so the rebuild's (distance, id) tie-breaks match
the mutated engine's.

Covered corners, per the PR-10 issue:
  * delete-then-reinsert of the same id (the kill-seq rule needs no
    special case: the reinsert's kill masks every older copy),
  * delete of a row currently sitting in a lane's top-k,
  * compaction racing concurrent query() — lock-order recorder wraps
    the engine/delta locks and asserts the observed graph is acyclic,
  * empty-delta and all-deleted-leaf corners.
"""

import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import IndexSpec, StoreSpec
from repro.core import guarantees as G
from repro.core.engine import DistributedEngine
from repro.store.delta import DeltaTier

pytestmark = pytest.mark.tier1

N, L, K = 256, 64, 5

# the taxonomy every parity check runs under: exact, epsilon-approx,
# delta-epsilon, and the ng (nprobe) regime. ng's contract is "visit
# nprobe leaves of THIS tree", and the rebuild's tree shape
# legitimately differs — so the parity runs ng at a saturating nprobe
# (every leaf visited: the ng path executes, the answer is
# tree-shape-free)
TAXONOMY = (G.exact(), G.epsilon(1.0), G.delta_epsilon(0.99, 0.5),
            G.ng(64))


def _znorm(x):
    return ((x - x.mean(1, keepdims=True))
            / (x.std(1, keepdims=True) + 1e-9)).astype(np.float32)


def _dataset(seed=7, n=N):
    rng = np.random.default_rng(seed)
    base = _znorm(np.cumsum(rng.normal(size=(n, L)), axis=1))
    q = _znorm(base[rng.choice(n, 6, replace=False)]
               + 0.05 * rng.normal(size=(6, L)))
    fresh = _znorm(np.cumsum(rng.normal(size=(16, L)), axis=1))
    return base, q, fresh


def _build(rows, spill, *, codec="f32", shards=2, **store_kw):
    return DistributedEngine(mesh=None, shards=shards).build(
        rows, index=IndexSpec("dstree", leaf_cap=32),
        store=StoreSpec(spill_dir=spill, codec=codec,
                        keep_resident=False, **store_kw))


def _assert_parity(eng, live_rows, live_ids, queries, spill, tag,
                   *, codec="f32", shards=2, guarantees=TAXONOMY,
                   ooc_opts=None, ulp=0):
    """eng's answers == a from-scratch rebuild's, bit for bit.

    ``ulp=0`` demands bitwise-identical distances (the f32 legs).
    bf16/pq legs pass a small ulp budget: both sides run the one
    shared ``ops.sq_l2`` over identical row bytes, but XLA's matmul
    reduction tiling is pool-shape-dependent and the rebuild's leaf
    pools legitimately differ in width — a few float32 ulps is the
    reduction-order floor, orders of magnitude below any actual
    delta-scoring bug."""
    assert np.all(np.diff(live_ids) > 0), "oracle needs ascending ids"
    oracle = _build(live_rows, spill, codec=codec, shards=shards)
    try:
        for g in guarantees:
            r = eng.query(jnp.asarray(queries), K, g,
                          ooc_opts=ooc_opts)
            o = oracle.query(jnp.asarray(queries), K, g,
                             ooc_opts=ooc_opts)
            oi = live_ids[np.asarray(o.ids)]
            assert np.array_equal(np.asarray(r.ids), oi), \
                f"{tag} [{g.kind}]: ids diverge from rebuild"
            rd = np.asarray(r.dists)
            od = np.asarray(o.dists)
            tol = ulp * np.spacing(np.maximum(np.abs(rd),
                                              np.abs(od)))
            assert np.all(np.abs(rd - od) <= tol), \
                f"{tag} [{g.kind}]: dists diverge from rebuild " \
                f"(max {np.abs(rd - od).max()}, tol {ulp} ulp)"
    finally:
        oracle.close()


# --------------------------------------------------- codec x taxonomy
@pytest.mark.parametrize("codec", ["f32", "bf16", "pq"])
def test_mutation_parity_across_codecs_and_taxonomy(tmp_path, codec):
    """Insert + delete, parity across the taxonomy, then compact and
    re-check: the published segment must not move a single bit. pq
    runs single-shard (its codebooks need >= 256 rows) with a rerank
    wide enough that the exact re-rank covers every candidate — pq
    pruning depends on the trained codebooks, which legitimately
    differ between the engine and the rebuild."""
    shards = 1 if codec == "pq" else 2
    opts = {"rerank": 64} if codec == "pq" else None
    ulp = 0 if codec == "f32" else 4
    # pq cannot honor exact (ADC-scored stopping may prune the true
    # neighbor's leaf — the engine warns and serves epsilon/ng), and
    # its epsilon-early-stop answer depends on the trained codebooks,
    # which legitimately differ between the engine and the rebuild —
    # so the pq leg runs the codebook-free regimes: delta-epsilon
    # (histogram-quantile stop) and saturating ng
    gs = TAXONOMY if codec != "pq" else (
        G.delta_epsilon(0.99, 0.5), G.ng(64))
    base, q, fresh = _dataset()
    eng = _build(base, str(tmp_path / "sp"), codec=codec,
                 shards=shards)
    try:
        new_ids = np.asarray(eng.insert(fresh))
        eng.delete([3, 77, int(new_ids[2])])
        live_rows = np.concatenate(
            [np.delete(base, [3, 77], axis=0),
             np.delete(fresh, [2], axis=0)])
        live_ids = np.concatenate(
            [np.delete(np.arange(N), [3, 77]),
             np.delete(new_ids, [2])]).astype(np.int64)
        _assert_parity(eng, live_rows, live_ids, q,
                       str(tmp_path / "o1"), "pre-compact",
                       codec=codec, shards=shards, ooc_opts=opts,
                       ulp=ulp, guarantees=gs)
        assert eng.compact()
        _assert_parity(eng, live_rows, live_ids, q,
                       str(tmp_path / "o2"), "post-compact",
                       codec=codec, shards=shards, ooc_opts=opts,
                       ulp=ulp, guarantees=gs)
    finally:
        eng.close()


# ----------------------------------------------- delete-then-reinsert
def test_delete_then_reinsert_same_id(tmp_path):
    """The reinsert's kill masks the frozen copy; the new active row
    is newest by construction — no special case, and parity holds with
    the row REPLACED in the oracle (ids unchanged, still ascending)."""
    base, q, fresh = _dataset()
    rid = 42
    eng = _build(base, str(tmp_path / "sp"))
    try:
        eng.delete([rid])
        gone = eng.query(jnp.asarray(base[rid:rid + 1]), K, G.exact())
        assert rid not in np.asarray(gone.ids)

        replacement = fresh[0]
        got = np.asarray(eng.insert(replacement, ids=[rid]))
        assert got.tolist() == [rid]
        hit = eng.query(jnp.asarray(replacement[None]), 1, G.exact())
        assert int(np.asarray(hit.ids)[0, 0]) == rid
        assert float(np.asarray(hit.dists)[0, 0]) == 0.0

        live_rows = base.copy()
        live_rows[rid] = replacement
        live_ids = np.arange(N, dtype=np.int64)
        _assert_parity(eng, live_rows, live_ids, q,
                       str(tmp_path / "o1"), "reinserted")
        # and the OLD bytes must stay dead after the memtable freezes
        assert eng.compact()
        _assert_parity(eng, live_rows, live_ids, q,
                       str(tmp_path / "o2"), "reinserted+compacted")
    finally:
        eng.close()


# ------------------------------------------- delete out of a top-k
def test_delete_of_row_in_running_topk(tmp_path):
    """Tombstoning every lane's rank-1 id between queries: the next
    query must not surface any of them, and the refilled top-k is
    bit-exact vs a rebuild without those rows."""
    base, q, _ = _dataset()
    eng = _build(base, str(tmp_path / "sp"))
    try:
        first = eng.query(jnp.asarray(q), K, G.exact())
        victims = sorted(set(np.asarray(first.ids)[:, 0].tolist()))
        eng.delete(victims)
        second = eng.query(jnp.asarray(q), K, G.exact())
        assert not np.isin(np.asarray(second.ids), victims).any()
        keep = ~np.isin(np.arange(N), victims)
        _assert_parity(eng, base[keep],
                       np.arange(N, dtype=np.int64)[keep], q,
                       str(tmp_path / "o"), "topk-delete")
    finally:
        eng.close()


# ------------------------------------- compaction vs concurrent query
def test_compaction_racing_concurrent_query(tmp_path):
    """Writer thread streams inserts past the auto-compact threshold
    while reader threads keep query() in flight: every in-race answer
    is well-formed, at least one background compaction lands, the
    lock-order recorder's observed graph is acyclic, and the final
    state is bit-exact vs a rebuild."""
    base, q, _ = _dataset()
    rng = np.random.default_rng(13)
    stream = _znorm(np.cumsum(rng.normal(size=(96, L)), axis=1))
    eng = _build(base, str(tmp_path / "sp"), delta_max_rows=16,
                 auto_compact=True, compact_interval_s=0.005)
    rec = obs.LockOrderRecorder()
    eng._write_lock = rec.wrap(eng._write_lock, "engine._write_lock")
    eng.enable_writes()
    eng._delta._lock = rec.wrap(eng._delta._lock, "delta._lock")
    errors = []
    qj = jnp.asarray(q)

    def reader():
        try:
            for _ in range(8):
                res = eng.query(qj, K, G.exact())
                ids = np.asarray(res.ids)
                assert ids.shape == (len(q), K)
                assert (ids >= 0).all(), "padding surfaced mid-race"
        except BaseException as e:  # noqa: BLE001 re-raised on the main thread below: a bare thread swallows its exception and the test would pass vacuously
            errors.append(e)

    def writer():
        try:
            for i in range(0, len(stream), 8):
                eng.insert(stream[i:i + 8])
        except BaseException as e:  # noqa: BLE001 same re-raise trampoline as reader
            errors.append(e)

    threads = [threading.Thread(target=f)
               for f in (writer, reader, reader)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert not errors, errors
    # drain: one manual compact() mops up whatever the daemon's last
    # tick left in the memtable, then the graph + parity checks
    eng.compact()
    rec.assert_acyclic()
    assert len(eng._delta.segments()) >= 1
    live_rows = np.concatenate([base, stream])
    live_ids = np.arange(N + len(stream), dtype=np.int64)
    # 96 streamed rows reshape the rebuild's tree substantially, so
    # the epsilon-early-stop regimes legitimately answer differently
    # (each satisfies its bound on its OWN tree) — the post-race
    # parity runs the tree-shape-free regimes
    _assert_parity(eng, live_rows, live_ids, q, str(tmp_path / "o"),
                   "post-race", ulp=4,
                   guarantees=(G.exact(), G.ng(64)))
    eng.close()


# --------------------------------------------------------- the corners
def test_empty_delta_is_invisible(tmp_path):
    """Arming the write path without writing must not perturb serving:
    same answers bit for bit, compact() is a no-op."""
    base, q, _ = _dataset()
    eng = _build(base, str(tmp_path / "sp"))
    try:
        before = eng.query(jnp.asarray(q), K, G.exact())
        eng.enable_writes()
        assert eng.compact() is False
        after = eng.query(jnp.asarray(q), K, G.exact())
        assert np.array_equal(np.asarray(before.ids),
                              np.asarray(after.ids))
        assert np.array_equal(np.asarray(before.dists),
                              np.asarray(after.dists))
    finally:
        eng.close()


def test_insert_then_delete_all_never_freezes(tmp_path):
    """A memtable whose every row is already killed has nothing to
    compact (begin_freeze folds to None) and serves exactly the frozen
    base."""
    base, q, fresh = _dataset()
    eng = _build(base, str(tmp_path / "sp"))
    try:
        ids = np.asarray(eng.insert(fresh))
        eng.delete(ids)
        assert eng.compact() is False
        _assert_parity(eng, base, np.arange(N, dtype=np.int64), q,
                       str(tmp_path / "o"), "all-deleted-delta")
    finally:
        eng.close()


def test_all_deleted_leaf(tmp_path):
    """Tombstone an entire leaf's worth of contiguous ids: the dead
    leaf must contribute nothing (no padding ids, no dead ids) and the
    rest of the answer is bit-exact vs a rebuild without those rows."""
    base, q, _ = _dataset()
    dead = np.arange(32)  # leaf_cap ids off the front of shard 0
    eng = _build(base, str(tmp_path / "sp"))
    try:
        eng.delete(dead)
        res = eng.query(jnp.asarray(q), K, G.exact())
        ids = np.asarray(res.ids)
        assert (ids >= 0).all()
        assert not np.isin(ids, dead).any()
        keep = ~np.isin(np.arange(N), dead)
        _assert_parity(eng, base[keep],
                       np.arange(N, dtype=np.int64)[keep], q,
                       str(tmp_path / "o"), "dead-leaf", ulp=4)
    finally:
        eng.close()


# ------------------------------------------------- DeltaTier unit law
def test_kill_seq_rule_on_the_tier_itself():
    """The tier-level invariant the engine builds on: at most one live
    copy of any id across active + immutable, and a unit's copy is
    dead iff some kill outruns its birth."""
    tier = DeltaTier(4, start_id=100)
    ids = tier.insert(np.zeros((2, 4), np.float32))
    assert ids.tolist() == [100, 101]
    tier.delete([100])
    snap = tier.snapshot()
    assert snap.ids.tolist() == [101]
    # frozen copy born at seq 0 is masked; one born AFTER the kill
    # (e.g. a compacted segment) is not
    mask_old = snap.dead_mask(np.asarray([100]), born_seq=0)
    mask_new = snap.dead_mask(np.asarray([100]),
                              born_seq=snap.kills[100])
    assert mask_old.tolist() == [True]
    assert mask_new.tolist() == [False]
    # reinsert: the id is live again, the old frozen copy stays dead
    tier.insert(np.ones((1, 4), np.float32), ids=[100])
    snap = tier.snapshot()
    assert sorted(snap.ids.tolist()) == [100, 101]
    assert snap.dead_mask(np.asarray([100]),
                          born_seq=0).tolist() == [True]
