"""Per-architecture smoke tests (deliverable f): reduced config of the
same family, one train step + decode on CPU, shape/NaN assertions, and
prefill->decode cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M
from repro.models.params import initialize, param_count
from repro.train import optimizer as opt_mod
from repro.train.train_step import build_train_step

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_frames, cfg.d_model), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = initialize(M.model_specs(cfg), KEY)
    batch = make_batch(cfg)
    loss, metrics = M.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss), arch
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = build_train_step(cfg, ocfg)
    opt_state = opt_mod.init(ocfg, params)
    new_params, _, m2 = jax.jit(step)(params, opt_state, batch)
    assert jnp.isfinite(m2["loss"])
    # params actually changed
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32)
                      - b.astype(jnp.float32)).sum())
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    params = initialize(M.model_specs(cfg), KEY)
    batch = make_batch(cfg)
    pre_in = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = M.prefill(params, pre_in, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    lg, cache2 = M.decode_step(params, tok, cache, jnp.int32(S - 1), cfg)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), arch


@pytest.mark.parametrize("arch", ["llama3-405b", "gemma2-2b",
                                  "mamba2-370m", "jamba-v0.1-52b",
                                  "deepseek-moe-16b"])
def test_prefill_decode_consistency(arch):
    """Decoding token t from the cache must reproduce the logits of a
    full forward at position t — validates KV/SSM cache correctness for
    attention, local attention, SSD, hybrid and MoE stacks.

    Runs in f32 (tight tolerance); MoE capacity is raised so prefill
    (T=B*S tokens) and decode (T=B) route identically — capacity drops
    are batch-size-dependent by design."""
    import dataclasses

    import jax.numpy as jnp_

    cfg = get_smoke_config(arch)
    over = dict(param_dtype=jnp_.float32, compute_dtype=jnp_.float32)
    if cfg.moe is not None:
        over["moe"] = cfg.moe._replace(capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, **over)
    params = initialize(M.model_specs(cfg), KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    # full forward logits at position S-1 given prefix [0, S-1)
    logits_pre, _ = M.prefill(params, {"tokens": toks}, cfg)

    # prefill on the first S-1 tokens, then decode token S-1
    logits_p, cache = M.prefill(params, {"tokens": toks[:, :S - 1]}, cfg)
    from repro.serve.serve_step import _grow_cache

    cache = _grow_cache(cache, S)
    lg, _ = M.decode_step(params, toks[:, S - 1:S], cache,
                          jnp.int32(S - 1), cfg)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(logits_pre[:, 0], np.float32),
        atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_specs_construct(arch):
    """Full (paper-scale) configs must build spec trees with the exact
    published dimensions — no allocation."""
    cfg = get_config(arch)
    n = param_count(M.model_specs(cfg))
    expected = {
        "llama3-405b": (380e9, 430e9),
        "minitron-8b": (8e9, 11e9),
        "qwen1.5-110b": (100e9, 120e9),
        "gemma2-2b": (2.2e9, 3.2e9),
        "seamless-m4t-medium": (0.4e9, 1.2e9),
        "dbrx-132b": (120e9, 140e9),
        "deepseek-moe-16b": (14e9, 18e9),
        "jamba-v0.1-52b": (48e9, 55e9),
        "chameleon-34b": (30e9, 37e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }[arch]
    assert expected[0] <= n <= expected[1], (arch, n)


def test_moe_active_params_below_total():
    cfg = get_config("dbrx-132b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
