"""Paper Figure 2: indexing scalability (build time) + footprint."""

from __future__ import annotations

import time
from typing import List

import jax

from repro.core.indexes import dstree, graph, imi, isax, srs, vafile
from repro.data import randomwalk

from .common import csv_line, emit


def _footprint_bytes(idx) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(idx):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total


BUILDERS = {
    "isax2+": lambda d: isax.build(d, leaf_cap=256),
    "dstree": lambda d: dstree.build(d, leaf_cap=256),
    "va+file": lambda d: vafile.build(d),
    "imi": lambda d: imi.build(d, kc=16, m=16, kmeans_iters=10),
    "srs": lambda d: srs.build(d, m=16),
    "hnsw": lambda d: graph.build(d, m_links=8),
}


def run(scale: str = "default", out_dir=None) -> List[dict]:
    sizes = {"small": (1024, 2048), "default": (2048, 4096, 8192),
             "large": (8192, 16384, 32768)}[scale]
    rows = []
    for n in sizes:
        data = randomwalk.generate(11, n, 128)
        raw_bytes = data.nbytes
        for name, build in BUILDERS.items():
            t0 = time.perf_counter()
            idx = build(data)
            dt = time.perf_counter() - t0
            fp = _footprint_bytes(idx)
            rows.append({
                "bench": "indexing", "method": name, "n": n,
                "build_seconds": dt,
                "footprint_bytes": fp,
                "footprint_over_raw": fp / raw_bytes,
            })
            print(csv_line(
                f"indexing/{name}/n{n}", dt * 1e6,
                f"footprint_ratio={fp / raw_bytes:.2f}"))
    emit(rows, out_dir, "bench_indexing")
    return rows
