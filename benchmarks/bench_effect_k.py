"""Paper Figure 7: cost vs k — finding the first neighbor dominates;
additional neighbors are cheap."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import dstree, isax

from .common import csv_line, dataset, emit, timeit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    rows: List[dict] = []
    built = {
        "dstree": dstree.build(data, leaf_cap=256),
        "isax2+": isax.build(data, leaf_cap=256),
    }
    for name, idx in built.items():
        for k in (1, 10, 25, 50, 100):
            fn = lambda idx=idx, kk=k: S.search(idx, qj, kk,
                                                G.epsilon(1.0))
            res = fn()
            sec = timeit(fn, repeats=3)
            rows.append({
                "bench": "effect_k", "method": name, "k": k,
                "seconds_per_workload": sec,
                "leaves": float(res.leaves_visited.mean()),
            })
            print(csv_line(f"effk/{name}/k{k}", sec / len(q) * 1e6,
                           f"leaves={float(res.leaves_visited.mean()):.0f}"))
    emit(rows, out_dir, "bench_effect_k")
    return rows
