"""Closed-loop latency-vs-load bench: static barrier front vs the
continuous-batching front (benchmarks the PR 9 tentpole).

BENCH_pr5 reported serving throughput as ONE number — requests/s with
every request already queued. That cannot express the thing a serving
front is for: what happens to latency as offered load rises. This
bench drives BOTH fronts with an open-loop paced submitter (requests
arrive at a fixed rate whether or not the server keeps up — the
industry-standard way to expose queueing collapse) and sweeps the
arrival rate across multiples of the calibrated base service rate:

  static      a Scheduler drained one batch at a time on a single
              server thread — drain, group by guarantee, serve each
              group to completion, repeat (the barrier loop
              launch/serve.serve_requests models).
  continuous  serve/loop.ServeFront — per-guarantee lanes refilling
              as engine calls complete, admission control (depth cap
              + reject), hysteresis shedding degrading tiers under
              sustained pressure.

Per load point and mode it reports p50/p99 end-to-end latency (submit
-> answer, on the one obs.now clock, quantiles via the repro.obs
log-bucketed histograms), achieved throughput, the DEGRADED-TIER
fraction (answers whose final tier is below the tier their submitted
deadline nominally buys — remaining-budget remapping + shedding make
this the quality price of load), and rejected counts. The summary
compares the fronts at the top load point: the continuous front must
beat the static barrier on p99 there (or the snapshot gate fails —
benchmarks/compare.py `serve_load` section).

    PYTHONPATH=src python -m benchmarks.bench_serve_load [--scale default]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import IndexSpec, StoreSpec
from repro.core.engine import DistributedEngine
from repro.core.guarantees import Guarantee
from repro.serve.admission import AdmissionController
from repro.serve.batching import (Request, Scheduler,
                                  guarantee_for_deadline)
from repro.serve.loop import Rejected, ServeFront

from .common import dataset

# the deadline mix every load point cycles through: no-deadline
# (exact tier), relaxed (epsilon-tier budget), moderate
# (delta-epsilon), tight (ng)
DEADLINE_MIX = (None, 80.0, 40.0, 10.0)
TIER_RANK = {"exact": 0, "epsilon": 0, "delta-epsilon": 1, "ng": 2}
LOAD_FACTORS = (0.5, 1.0, 2.0, 4.0)
SMOKE_FACTORS = (1.0, 4.0)
POINT_TIMEOUT_S = 180.0


def _degraded(nominal_kind: str, final_kind: str) -> bool:
    return TIER_RANK[final_kind] > TIER_RANK[nominal_kind]


def _mk_request(uid: int, q: np.ndarray, dl: Optional[float]) -> Request:
    # Request stamps submitted_at on construction (obs.now) — build it
    # at its paced arrival instant, never ahead of time
    return Request(uid=uid, prompt=np.zeros(4, np.int32),
                   deadline_ms=dl, series=q)


def _paced_submit(n_reqs: int, queries: np.ndarray, rate_rps: float,
                  submit_one) -> None:
    """Open-loop arrivals: request i is submitted at start + i/rate
    regardless of server progress (late submitters never slow the
    offered load down — that would be closed-loop coordination
    masking the queueing collapse this bench exists to show)."""
    start = obs.now()
    for i in range(n_reqs):
        target = start + i / rate_rps
        delay = target - obs.now()
        if delay > 0:
            time.sleep(delay)
        dl = DEADLINE_MIX[i % len(DEADLINE_MIX)]
        submit_one(_mk_request(i, queries[i % len(queries)], dl))


def _point_summary(lat_ms: Dict[int, float],
                   kinds: Dict[int, str],
                   nominal: Dict[int, str],
                   n_offered: int, wall_s: float,
                   rejected: int) -> Dict[str, Any]:
    hist = obs.Histogram("bench.serve_load.latency_ms", ())
    for v in lat_ms.values():
        hist.record(v)
    answered = len(lat_ms)
    degraded = sum(1 for u in kinds if _degraded(nominal[u], kinds[u]))
    qn = hist.quantiles((0.5, 0.99))
    return {
        "answered": answered,
        "rejected": rejected,
        "achieved_rps": round(answered / wall_s, 1) if wall_s else 0.0,
        "p50_ms": round(qn["p50"], 3) if answered else None,
        "p99_ms": round(qn["p99"], 3) if answered else None,
        "degraded_frac": round(degraded / answered, 4) if answered
        else None,
    }


def _static_point(eng, queries, k, n_reqs, rate_rps,
                  max_batch) -> Dict[str, Any]:
    """The barrier loop: one server thread drains one batch at a time
    and serves it to completion before the next drain."""
    sched = Scheduler(max_batch=max_batch)
    done: Dict[int, Dict[str, Any]] = {}
    done_at: Dict[int, float] = {}
    submit_at: Dict[int, float] = {}
    done_lock = threading.Lock()
    submitted = threading.Event()

    def submit_one(r: Request):
        submit_at[r.uid] = r.submitted_at
        sched.submit(r)

    def server():
        while True:
            nb = sched.next_batch()
            if nb is None:
                if submitted.is_set():
                    with done_lock:
                        if len(done) >= n_reqs:
                            return
                time.sleep(0.0005)
                continue
            _bucket, batch = nb
            out = sched.run_retrieval(eng, batch, k)
            t = obs.now()
            with done_lock:
                for uid, entry in out.items():
                    done[uid] = entry
                    done_at[uid] = t

    srv = threading.Thread(target=server, daemon=True)
    srv.start()
    t0 = obs.now()
    _paced_submit(n_reqs, queries, rate_rps, submit_one)
    submitted.set()
    srv.join(timeout=POINT_TIMEOUT_S)
    wall_s = max(obs.now() - t0, 1e-9)
    lat = {u: (done_at[u] - submit_at[u]) * 1e3 for u in done}
    kinds = {u: done[u]["kind"] for u in done}
    nominal = {u: guarantee_for_deadline(
        DEADLINE_MIX[u % len(DEADLINE_MIX)]).kind for u in done}
    return _point_summary(lat, kinds, nominal, n_reqs, wall_s, 0)


def _continuous_point(eng, queries, k, n_reqs, rate_rps, max_batch,
                      max_depth) -> Dict[str, Any]:
    tickets: Dict[int, Any] = {}
    rejected = [0]
    front = ServeFront(
        eng, k, max_batch=max_batch,
        admission=AdmissionController(max_depth=max_depth)).start()

    def submit_one(r: Request):
        try:
            tickets[r.uid] = (r.submitted_at, front.submit(r))
        except Rejected:
            rejected[0] += 1

    t0 = obs.now()
    try:
        _paced_submit(n_reqs, queries, rate_rps, submit_one)
        outs = {u: (sub, t.result(timeout=POINT_TIMEOUT_S))
                for u, (sub, t) in tickets.items()}
    finally:
        front.stop(drain=True)
    wall_s = max(obs.now() - t0, 1e-9)
    outs = {u: (sub, o) for u, (sub, o) in outs.items()
            if "error" not in o}
    lat = {u: (o["done_at"] - sub) * 1e3 for u, (sub, o) in outs.items()}
    kinds = {u: o["kind"] for u, (_s, o) in outs.items()}
    nominal = {u: guarantee_for_deadline(
        DEADLINE_MIX[u % len(DEADLINE_MIX)]).kind for u in outs}
    return _point_summary(lat, kinds, nominal, n_reqs, wall_s,
                          rejected[0])


def _freshness_probe(engine, data: np.ndarray, k: int,
                     n_writes: int = 16) -> Dict[str, Any]:
    """Freshness: insert -> first-retrievable lag through the write
    lane (docs/INGEST.md). Two stamps per write: ``applied_ms`` is
    submit -> the write lane's ``applied_at`` (the mutation is in the
    delta memtable), ``visible_ms`` is submit -> a query() observing
    the new row in its answer — the metric the delta tier exists to
    bound. Probes run against the warm engine AFTER the load curve so
    the latency points stay a pure frozen-corpus measurement; the
    probe rows are deleted again on the way out."""
    rng = np.random.default_rng(11)
    rows = np.cumsum(rng.normal(size=(n_writes, data.shape[1])),
                     axis=1)
    rows = ((rows - rows.mean(1, keepdims=True))
            / (rows.std(1, keepdims=True) + 1e-9)).astype(np.float32)
    applied = obs.Histogram("bench.freshness.applied_ms", ())
    visible = obs.Histogram("bench.freshness.visible_ms", ())
    inserted: List[int] = []
    all_seen = True
    front = ServeFront(engine, k, max_batch=8).start()
    try:
        for i in range(n_writes):
            t_sub = obs.now()
            entry = front.submit_write(
                "insert", rows=rows[i:i + 1]).result(
                    timeout=POINT_TIMEOUT_S)
            applied.record((entry["applied_at"] - t_sub) * 1e3)
            # the probe queries for the inserted series verbatim: the
            # first query after applied_at must already return it
            got = engine.query(jnp.asarray(rows[i:i + 1]), 1,
                               Guarantee())
            visible.record((obs.now() - t_sub) * 1e3)
            gid = int(np.asarray(entry["ids"])[0])
            inserted.append(gid)
            all_seen &= int(np.asarray(got.ids)[0, 0]) == gid
    finally:
        front.stop(drain=True)
        if inserted:
            engine.delete(inserted)
    aq = applied.quantiles((0.5, 0.99))
    vq = visible.quantiles((0.5, 0.99))
    return {
        "n_writes": n_writes,
        "applied_ms_p50": round(aq["p50"], 3),
        "applied_ms_p99": round(aq["p99"], 3),
        "visible_ms_p50": round(vq["p50"], 3),
        "visible_ms_p99": round(vq["p99"], 3),
        "retrievable_immediately": bool(all_seen),
    }


def run(scale: str = "default", smoke: bool = False,
        engine=None) -> Dict[str, Any]:
    """Collect the ``serve_load`` snapshot section: the latency-vs-
    load curve for both fronts plus the head-to-head summary."""
    data, q, _bf, p = dataset(scale)
    k = p["k"]
    q = np.asarray(q, np.float32)
    factors = SMOKE_FACTORS if smoke else LOAD_FACTORS
    n_reqs = max(16, len(q)) if smoke else 2 * len(q)
    max_batch = 8

    own_engine = engine is None
    tmp = None
    if own_engine:
        tmp = tempfile.TemporaryDirectory()
        mesh = jax.make_mesh((1,), ("data",))
        engine = DistributedEngine(mesh, method="dstree")
        engine.build(data, index=IndexSpec("dstree", leaf_cap=256),
                     store=StoreSpec(spill_dir=os.path.join(tmp.name,
                                                            "sp"),
                                     codec="bf16",
                                     keep_resident=False))
    try:
        # warm the leaf caches AND the per-kind lane-bucket shapes the
        # paced runs will drain (groups of 1, 2, 4, ... per kind —
        # requests must be freshly stamped per warm call, or the
        # remaining-budget remap maps their spent deadlines to ng
        # only), then calibrate the base service rate from a
        # back-to-back serve of the full mix
        sched = Scheduler(max_batch=max_batch)
        size = 1
        while size <= max_batch:
            wreqs = [_mk_request(i, q[i % len(q)],
                                 DEADLINE_MIX[i % len(DEADLINE_MIX)])
                     for i in range(size * len(DEADLINE_MIX))]
            sched.run_retrieval(engine, wreqs, k)
            size *= 2
        warm = [_mk_request(i, q[i % len(q)],
                            DEADLINE_MIX[i % len(DEADLINE_MIX)])
                for i in range(max(len(q), 8))]
        t0 = obs.now()
        sched.run_retrieval(engine, warm, k)
        base_rate = len(warm) / max(obs.now() - t0, 1e-9)

        points: List[Dict[str, Any]] = []
        for f in factors:
            rate = f * base_rate
            stat = _static_point(engine, q, k, n_reqs, rate, max_batch)
            cont = _continuous_point(engine, q, k, n_reqs, rate,
                                     max_batch,
                                     max_depth=max(4 * max_batch, 32))
            points.append({"load_factor": f,
                           "offered_rps": round(rate, 1),
                           "static": stat, "continuous": cont})
        freshness = _freshness_probe(engine, data, k,
                                     n_writes=4 if smoke else 16)
        top = points[-1]
        beats = (top["continuous"]["p99_ms"] is not None
                 and top["static"]["p99_ms"] is not None
                 and top["continuous"]["p99_ms"]
                 <= top["static"]["p99_ms"])
        return {
            "base_rate_rps": round(base_rate, 1),
            "n_requests": n_reqs,
            "deadline_mix_ms": list(DEADLINE_MIX),
            "points": points,
            "freshness": freshness,
            "summary": {
                "top_load_factor": top["load_factor"],
                "static_p99_ms": top["static"]["p99_ms"],
                "continuous_p99_ms": top["continuous"]["p99_ms"],
                "continuous_beats_static": bool(beats),
            },
        }
    finally:
        if own_engine:
            engine.close()
            tmp.cleanup()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", default="default",
                    choices=("small", "default", "large"))
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    out = run(args.scale, smoke=args.smoke)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
