"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale small|default|large]
                                            [--only fig3,fig8,...]
    PYTHONPATH=src python -m benchmarks.run --snapshot           # perf
        trajectory: writes the current snapshot (benchmarks/snapshot.py
        SNAPSHOT_NAME, e.g. BENCH_pr5.json; override the path with
        --out) at the repo root — kernel µs, bytes-read, queries/s and
        the out-of-core serving rows at the default scale
    PYTHONPATH=src python -m benchmarks.run --snapshot --smoke   # the
        scripts/verify.sh gate: compile+run every snapshot path once at
        the small scale, write nothing

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and
writes JSON rows under experiments/bench/."""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

SUITES = {
    "fig2_indexing": "benchmarks.bench_indexing",
    "fig3_query_memory": "benchmarks.bench_query_memory",
    "fig4_query_disk": "benchmarks.bench_query_disk",
    "fig5_accuracy_measures": "benchmarks.bench_accuracy_measures",
    "fig6_best_methods": "benchmarks.bench_best_methods",
    "fig7_effect_k": "benchmarks.bench_effect_k",
    "fig8_delta_epsilon": "benchmarks.bench_delta_epsilon",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.bench_roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None,
                    choices=["small", "default", "large"],
                    help="bench scale (figure suites default to small; "
                         "--snapshot defaults to default)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (substring match)")
    ap.add_argument("--out", default=None,
                    help="figure suites: JSON output dir (default "
                         "experiments/bench). --snapshot: the snapshot "
                         "file path (default: snapshot.SNAPSHOT_NAME "
                         "at the repo root, e.g. --out BENCH_pr5.json)")
    ap.add_argument("--snapshot", action="store_true",
                    help="write the perf-trajectory snapshot "
                         "(snapshot.SNAPSHOT_NAME or --out) at the "
                         "repo root instead of running the figure "
                         "suites")
    ap.add_argument("--smoke", action="store_true",
                    help="with --snapshot: compile+run once at the "
                         "small scale, write nothing (verify.sh gate)")
    args = ap.parse_args()

    if args.smoke and not args.snapshot:
        ap.error("--smoke only applies to --snapshot")
    if args.snapshot:
        if args.only is not None:
            ap.error("--only does not apply to --snapshot")
        if args.smoke and args.out is not None:
            ap.error("--out does not apply to --smoke (writes nothing)")
        from . import snapshot

        out_path = None
        if args.out is not None:
            out_path = args.out if os.path.dirname(args.out) \
                else snapshot._repo_root_path(args.out)
        # explicit --scale is honored; --smoke shrinks the default
        scale = args.scale or ("small" if args.smoke else "default")
        snapshot.run_snapshot(scale=scale, smoke=args.smoke,
                              out_path=out_path)
        return

    args.scale = args.scale or "small"
    args.out = args.out or "experiments/bench"

    import importlib

    failures = 0
    print("name,us_per_call,derived")
    for key, modname in SUITES.items():
        if args.only and not any(tok in key
                                 for tok in args.only.split(",")):
            continue
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(modname)
            mod.run(args.scale, out_dir=args.out)
            print(f"# {key} done in {time.perf_counter() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {key} FAILED", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
