"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale small|default|large]
                                            [--only fig3,fig8,...]

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and
writes JSON rows under experiments/bench/."""

from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = {
    "fig2_indexing": "benchmarks.bench_indexing",
    "fig3_query_memory": "benchmarks.bench_query_memory",
    "fig4_query_disk": "benchmarks.bench_query_disk",
    "fig5_accuracy_measures": "benchmarks.bench_accuracy_measures",
    "fig6_best_methods": "benchmarks.bench_best_methods",
    "fig7_effect_k": "benchmarks.bench_effect_k",
    "fig8_delta_epsilon": "benchmarks.bench_delta_epsilon",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.bench_roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "default", "large"])
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (substring match)")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    import importlib

    failures = 0
    print("name,us_per_call,derived")
    for key, modname in SUITES.items():
        if args.only and not any(tok in key
                                 for tok in args.only.split(",")):
            continue
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(modname)
            mod.run(args.scale, out_dir=args.out)
            print(f"# {key} done in {time.perf_counter() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {key} FAILED", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
