"""Paper Figure 6: the two best disk methods (DSTree vs iSAX2+) in
depth — data accessed and random I/O across the accuracy range, plus
the beyond-paper tightened-box iSAX variant."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import dstree, isax
from repro.core.metrics import workload_metrics

from .common import csv_line, dataset, emit, timeit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k, n = p["k"], p["n"]
    rows: List[dict] = []

    variants = {
        "dstree": dstree.build(data, leaf_cap=256),
        "isax2+": isax.build(data, leaf_cap=256),
        "isax2+tight": isax.build(data, leaf_cap=256, tighten=True),
    }
    for name, idx in variants.items():
        for eps in (5.0, 2.0, 1.0, 0.5, 0.0):
            fn = lambda idx=idx, e=eps: S.search(
                idx, qj, k, G.delta_epsilon(0.99, e))
            res = fn()
            sec = timeit(fn, repeats=3)
            m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
            rows.append({
                "bench": "best_methods", "method": name, "eps": eps,
                "throughput_qps": len(q) / sec,
                "data_accessed_frac":
                    float(res.rows_scanned.mean()) / n,
                "random_ios": float(res.leaves_visited.mean()), **m,
            })
            print(csv_line(
                f"best/{name}/eps{eps}", sec / len(q) * 1e6,
                f"map={m['map']:.3f};"
                f"data={float(res.rows_scanned.mean()) / n:.4f};"
                f"ios={float(res.leaves_visited.mean()):.0f}"))
    emit(rows, out_dir, "bench_best_methods")
    return rows
