"""Shared benchmark harness: datasets, timing, CSV emission.

Scales are deliberately reduced vs the paper's 25-250 GB (this container
is a single CPU core); the *relative* comparisons and all
implementation-independent counters (the paper's own §4.1 measures:
%data accessed, random I/O = leaf gathers) are scale-meaningful. Every
module exposes run(scale) -> list[row dicts]; benchmarks.run prints the
consolidated `name,us_per_call,derived` CSV required by the harness.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as S
from repro.data import queries as queries_mod
from repro.data import randomwalk

SCALES = {
    "small": dict(n=4096, series_len=128, n_queries=16, k=10),
    "default": dict(n=16384, series_len=256, n_queries=32, k=10),
    "large": dict(n=65536, series_len=256, n_queries=64, k=10),
}


@functools.lru_cache(maxsize=4)
def dataset(scale: str):
    p = SCALES[scale]
    data = randomwalk.generate(11, p["n"], p["series_len"])
    q = queries_mod.noisy_queries(data, p["n_queries"])
    bf = S.brute_force(jnp.asarray(q), jnp.asarray(data), p["k"])
    jax.block_until_ready(bf.dists)
    return data, q, bf, p


def timeit(fn: Callable[[], Any], repeats: int = 3,
           warmup: int = 1) -> float:
    """Median seconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(rows: List[Dict[str, Any]], out_dir: Optional[str],
         name: str) -> None:
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=2, default=str)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
