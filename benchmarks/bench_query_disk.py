"""Paper Figure 4: on-disk regime — implementation-independent costs.

No spinning disks here, so we report the paper's own hardware-neutral
measures: fraction of raw data touched (sequential I/O proxy) and leaf
gathers (random-I/O proxy), for the disk-capable methods only
(Table 1's last column: iSAX2+/DSTree/VA+file/IMI)."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import search as S
from repro.core.indexes import dstree, imi, isax, vafile
from repro.core.metrics import workload_metrics

from .common import csv_line, dataset, emit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k, n = p["k"], p["n"]
    rows: List[dict] = []

    def record(method, knob, res):
        m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
        frac = float(res.rows_scanned.mean()) / n
        gathers = float(res.leaves_visited.mean())
        rows.append({"bench": "query_disk", "method": method,
                     "knob": knob, "data_accessed_frac": frac,
                     "random_ios": gathers, **m})
        print(csv_line(f"qdisk/{method}/{knob}", gathers,
                       f"map={m['map']:.3f};data={frac:.4f}"))

    built = {
        "isax2+": (isax.build(data, leaf_cap=256), 1),
        "dstree": (dstree.build(data, leaf_cap=256), 1),
        "va+file": (vafile.build(data), 64),
    }
    for name, (idx, vb) in built.items():
        for eps in (2.0, 1.0, 0.0):
            record(name, f"eps{eps}",
                   S.search(idx, qj, k, delta=0.99, epsilon=eps,
                            visit_batch=vb))
    ii = imi.build(data, kc=16, m=16, kmeans_iters=10)
    for nprobe in (8, 64):
        record("imi", f"nprobe{nprobe}",
               imi.query(ii, qj, k, nprobe=nprobe))
    emit(rows, out_dir, "bench_query_disk")
    return rows
