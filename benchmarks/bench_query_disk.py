"""Paper Figure 4: the on-disk regime, measured for real.

The storage tier (repro.store) persists each index as a leaf-contiguous
on-disk artifact and serves queries with only the summaries on device,
so this bench now reports REAL out-of-core costs instead of the old
hardware-neutral proxies: bytes read from disk, device-cache hit rate,
h2d bytes, and wall time for a cold cache (first pass over the store)
vs a warm one (same batch again) — plus the paper's own
implementation-independent counters (%data accessed, leaf gathers =
random-I/O units) for continuity with Figure 4. IMI stays in-memory
(proxy columns only): its ADC scan has no leaf store yet.

The codec x share_gathers section measures the two bytes-read levers of
store format v2 on the paper's best tree (dstree): compressed leaf
payloads (bf16 halves every leaf read; pq streams uint8 codes — 64x
fewer payload bytes at series_len=256/pq_m=16 (1024B -> 16B per row),
plus the small exact re-rank reads) and cooperative scoring (every
gathered slot scored
against all query lanes, so each lane's bsf tightens from the whole
batch's I/O and the search stops earlier). Expected at matched recall:
bytes_read <= 0.55x (bf16) and <= 0.2x (pq) of f32.
"""

from __future__ import annotations

import os
import tempfile
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree, imi, isax, vafile
from repro.core.metrics import workload_metrics
from repro.store import DeviceLeafCache

from .common import csv_line, dataset, emit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k, n = p["k"], p["n"]
    rows: List[dict] = []

    built = {
        "isax2+": (isax.build(data, leaf_cap=256), 1),
        "dstree": (dstree.build(data, leaf_cap=256), 1),
        "va+file": (vafile.build(data), 64),
    }

    def timed_ooc(store, cache, vb, eps, share=False):
        t0 = time.perf_counter()
        out = S.search_ooc(store, qj, k, G.delta_epsilon(0.99, eps),
                           visit_batch=vb, cache=cache,
                           share_gathers=share)
        jax.block_until_ready(out.result.dists)
        return out, time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as tmp:
        for name, (idx, vb) in built.items():
            store_dir = idx.save(os.path.join(tmp, name))
            store = FrozenIndex.load(store_dir, resident="summaries")
            # device cache sized to an eighth of the leaves: strictly
            # smaller than any visited working set at eps<=1
            cap = max(store.num_leaves // 8, qj.shape[0] * vb)
            for eps in (2.0, 1.0, 0.0):
                cache = DeviceLeafCache(store, cap)
                cold, t_cold = timed_ooc(store, cache, vb, eps)
                cache.reset_counters()
                warm, t_warm = timed_ooc(store, cache, vb, eps)
                res = cold.result
                m = workload_metrics(res.ids, res.dists, bf.ids,
                                     bf.dists)
                frac = float(res.rows_scanned.mean()) / n
                gathers = float(res.leaves_visited.mean())
                rows.append({
                    "bench": "query_disk", "method": name,
                    "knob": f"eps{eps}",
                    "data_accessed_frac": frac,
                    "random_ios": gathers,
                    "bytes_read_cold": cold.stats["bytes_read"],
                    "bytes_read_warm": warm.stats["bytes_read"],
                    "bytes_h2d_cold": cold.stats["bytes_h2d"],
                    "cache_hit_rate_cold": cold.stats["hit_rate"],
                    "cache_hit_rate_warm": warm.stats["hit_rate"],
                    "cache_capacity_leaves": cap,
                    "dataset_bytes": cold.stats["dataset_bytes"],
                    "prefetch_bytes_read":
                        cold.stats.get("prefetch_bytes_read", 0),
                    "t_cold_s": t_cold, "t_warm_s": t_warm,
                    **m,
                })
                print(csv_line(
                    f"qdisk/{name}/eps{eps}", t_cold * 1e6,
                    f"map={m['map']:.3f};data={frac:.4f};"
                    f"MBread={cold.stats['bytes_read'] / 1e6:.2f};"
                    f"hit={cold.stats['hit_rate']:.2f};"
                    f"whit={warm.stats['hit_rate']:.2f}"))

        # ---- store format v2: codec x share_gathers on dstree ----
        idx, vb = built["dstree"]
        f32_read = None
        for codec in ("f32", "bf16", "pq"):
            store_dir = idx.save(os.path.join(tmp, f"dstree_{codec}"),
                                 codec=codec)
            store = FrozenIndex.load(store_dir, resident="summaries")
            cap = max(store.num_leaves // 8, qj.shape[0] * vb)
            for share in (False, True):
                cache = DeviceLeafCache(store, cap)
                cold, t_cold = timed_ooc(store, cache, vb, 1.0, share)
                cache.reset_counters()
                warm, t_warm = timed_ooc(store, cache, vb, 1.0, share)
                res = cold.result
                m = workload_metrics(res.ids, res.dists, bf.ids,
                                     bf.dists)
                read = cold.stats["bytes_read"]
                if codec == "f32" and not share:
                    f32_read = read
                ratio = read / f32_read if f32_read else float("nan")
                rows.append({
                    "bench": "query_disk", "method": "dstree",
                    "knob": f"{codec}/share{int(share)}",
                    "codec": codec, "share_gathers": share,
                    "bytes_read_cold": read,
                    "bytes_read_vs_f32": ratio,
                    "bytes_read_rerank":
                        cold.stats["bytes_read_rerank"],
                    "bytes_read_warm": warm.stats["bytes_read"],
                    "bytes_h2d_cold": cold.stats["bytes_h2d"],
                    "cache_hit_rate_cold": cold.stats["hit_rate"],
                    "cache_hit_rate_warm": warm.stats["hit_rate"],
                    "payload_bytes": os.path.getsize(
                        os.path.join(store_dir, "data.bin")),
                    "t_cold_s": t_cold, "t_warm_s": t_warm,
                    **m,
                })
                print(csv_line(
                    f"qdisk/dstree/{codec}/share{int(share)}",
                    t_cold * 1e6,
                    f"map={m['map']:.3f};"
                    f"MBread={read / 1e6:.2f};"
                    f"vs_f32={ratio:.3f};"
                    f"hit={cold.stats['hit_rate']:.2f}"))

        # ---- frontier-aware prefetch depth (ROADMAP follow-up) ----
        # the host frontier hands the prefetcher the next
        # depth x visit_batch windows instead of one; deeper lookahead
        # converts demand misses into prefetch hits (the delta is the
        # row-to-row prefetch_hit_rate change at identical bytes-read
        # semantics — the visit order is depth-invariant)
        idx, vb = built["dstree"]
        store_dir = idx.save(os.path.join(tmp, "dstree_pfd"))
        store = FrozenIndex.load(store_dir, resident="summaries")
        cap = max(store.num_leaves // 8, qj.shape[0] * vb)
        base_hit = None
        for depth in (1, 2, 4):
            cache = DeviceLeafCache(store, cap)
            t0 = time.perf_counter()
            out = S.search_ooc(store, qj, k,
                               G.delta_epsilon(0.99, 1.0),
                               visit_batch=vb, cache=cache,
                               prefetch_depth=depth)
            jax.block_until_ready(out.result.dists)
            t_cold = time.perf_counter() - t0
            st = out.stats
            pf_rate = st["prefetch_hits"] / max(st["misses"], 1)
            if depth == 1:
                base_hit = pf_rate
            rows.append({
                "bench": "query_disk", "method": "dstree",
                "knob": f"prefetch_depth{depth}",
                "prefetch_depth": depth,
                "prefetch_hits": st["prefetch_hits"],
                "misses": st["misses"],
                "prefetch_hit_rate": pf_rate,
                "prefetch_hit_rate_delta_vs_depth1":
                    pf_rate - base_hit,
                "bytes_read_cold": st["bytes_read"],
                "prefetch_bytes_read":
                    st.get("prefetch_bytes_read", 0),
                "t_cold_s": t_cold,
            })
            print(csv_line(
                f"qdisk/dstree/pfdepth{depth}", t_cold * 1e6,
                f"pfhit={pf_rate:.3f};"
                f"dvs1={pf_rate - base_hit:+.3f};"
                f"MBread={st['bytes_read'] / 1e6:.2f}"))

    # IMI has no leaf store yet: keep the paper's proxy counters
    ii = imi.build(data, kc=16, m=16, kmeans_iters=10)
    for nprobe in (8, 64):
        res = imi.query(ii, qj, k, G.ng(nprobe))
        m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
        frac = float(res.rows_scanned.mean()) / n
        gathers = float(res.leaves_visited.mean())
        rows.append({"bench": "query_disk", "method": "imi",
                     "knob": f"nprobe{nprobe}",
                     "data_accessed_frac": frac, "random_ios": gathers,
                     **m})
        print(csv_line(f"qdisk/imi/nprobe{nprobe}", gathers,
                       f"map={m['map']:.3f};data={frac:.4f}"))
    emit(rows, out_dir, "bench_query_disk")
    return rows
