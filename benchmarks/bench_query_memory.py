"""Paper Figure 3: in-memory query efficiency vs accuracy frontiers,
ng-approximate and delta-epsilon, all methods."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import dstree, graph, imi, isax, srs, vafile
from repro.core.metrics import workload_metrics

from .common import csv_line, dataset, emit, timeit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k = p["k"]
    rows: List[dict] = []

    def record(method, mode, knob, fn):
        res = fn()
        sec = timeit(fn, repeats=3)
        m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
        thr = len(q) / sec
        rows.append({"bench": "query_memory", "method": method,
                     "mode": mode, "knob": knob,
                     "throughput_qps": thr, **m})
        print(csv_line(f"qmem/{method}/{mode}/{knob}",
                       sec / len(q) * 1e6,
                       f"map={m['map']:.3f};qps={thr:.1f}"))

    # --- data series indexes: ng (nprobe) and delta-epsilon (eps) ---
    built = {
        "isax2+": (isax.build(data, leaf_cap=256), 1),
        "dstree": (dstree.build(data, leaf_cap=256), 1),
        "va+file": (vafile.build(data), 64),
    }
    for name, (idx, vb) in built.items():
        for nprobe in (1, 4, 16, 64):
            record(name, "ng", f"nprobe{nprobe}",
                   lambda idx=idx, np_=nprobe, vb=vb: S.search(
                       idx, qj, k, G.ng(np_), visit_batch=vb))
        for eps in (5.0, 2.0, 1.0, 0.5, 0.0):
            record(name, "deltaeps", f"eps{eps}",
                   lambda idx=idx, e=eps, vb=vb: S.search(
                       idx, qj, k, G.delta_epsilon(0.99, e),
                       visit_batch=vb))

    # --- multidimensional competitors ---
    gi = graph.build(data, m_links=8)
    for efs in (8, 32, 128):
        record("hnsw", "ng", f"efs{efs}",
               lambda e=efs: graph.query(gi, qj, k, efs=e))
    ii = imi.build(data, kc=16, m=16, kmeans_iters=10)
    for nprobe in (1, 8, 32):
        record("imi", "ng", f"nprobe{nprobe}",
               lambda n=nprobe: imi.query(ii, qj, k, G.ng(n)))
    si = srs.build(data, m=16)
    for delta in (0.5, 0.9, 0.99):
        record("srs", "deltaeps", f"delta{delta}",
               lambda d=delta: srs.query(si, qj, k,
                                         G.delta_epsilon(d, 0.0)))
    emit(rows, out_dir, "bench_query_memory")
    return rows
