"""Paper Figure 5: recall vs MAP vs MRE across methods — reproduces C4
(IMI's recall/MAP gap from skipping raw re-rank) and C5 (recall == MAP
for methods that re-rank on raw distances)."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import dstree, graph, imi, isax, srs, vafile
from repro.core.metrics import workload_metrics

from .common import csv_line, dataset, emit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k = p["k"]
    rows: List[dict] = []

    def record(method, res, note=""):
        m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
        gap = m["avg_recall"] - m["map"]
        rows.append({"bench": "accuracy_measures", "method": method,
                     "recall_map_gap": gap, "note": note, **m})
        print(csv_line(f"acc/{method}", 0.0,
                       f"recall={m['avg_recall']:.3f};map={m['map']:.3f};"
                       f"mre={m['mre']:.3f}"))

    di = dstree.build(data, leaf_cap=256)
    record("dstree", S.search(di, qj, k, G.ng(16)))
    xi = isax.build(data, leaf_cap=256)
    record("isax2+", S.search(xi, qj, k, G.ng(16)))
    vi = vafile.build(data)
    record("va+file", S.search(vi, qj, k, G.ng(1024), visit_batch=64))
    gi = graph.build(data, m_links=8)
    record("hnsw", graph.query(gi, qj, k, efs=64))
    si = srs.build(data, m=16)
    record("srs", srs.query(si, qj, k, G.Guarantee(delta=0.9)))
    ii = imi.build(data, kc=16, m=16, kmeans_iters=10)
    record("imi", imi.query(ii, qj, k, G.ng(32)),
           note="ADC only — no raw re-rank (paper C4)")
    record("imi+refine", imi.query(ii, qj, k, G.ng(32), refine=True),
           note="beyond-paper: raw re-rank closes the gap")
    emit(rows, out_dir, "bench_accuracy_measures")
    return rows
