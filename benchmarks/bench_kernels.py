"""Kernel microbenchmarks: µs/call for each hot-spot op.

On this CPU container the timed path is the jnp oracle (the production
XLA:CPU path); Pallas timings are meaningful only on TPU — interpret
mode is correctness-only. Both facts are recorded in the CSV note."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import csv_line, emit, timeit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    rng = np.random.default_rng(0)
    sizes = {"small": (64, 2048), "default": (128, 8192),
             "large": (256, 32768)}[scale]
    b, m = sizes
    n = 256
    q = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    lo = jnp.asarray(rng.normal(size=(m, 32)) - 1, jnp.float32)
    hi = lo + 0.5
    qs = jnp.asarray(rng.normal(size=(b, 32)), jnp.float32)
    w = jnp.ones((32,), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (m, 16)), jnp.int32)
    lut = jnp.asarray(rng.uniform(size=(16, 256)), jnp.float32)

    cases = {
        "paa": lambda: ops.paa(x, 16),
        "box_mindist": lambda: ops.box_mindist(qs, lo, hi, w),
        "l2": lambda: ops.l2(q, x),
        "l2_topk": lambda: ops.l2_topk(q, x, 10),
        "pq_adc": lambda: ops.pq_adc(codes, lut),
    }
    rows: List[dict] = []
    for name, fn in cases.items():
        jitted = jax.jit(fn)
        sec = timeit(jitted, repeats=5)
        rows.append({"bench": "kernels", "kernel": name,
                     "us_per_call": sec * 1e6,
                     "note": "XLA:CPU oracle path; Pallas validated in "
                             "interpret mode (tests/test_kernels.py)"})
        print(csv_line(f"kernel/{name}", sec * 1e6,
                       f"b={b};m={m};n={n}"))
    emit(rows, out_dir, "bench_kernels")
    return rows
