"""Kernel microbenchmarks: µs/call for each hot-spot op.

On this CPU container the timed path is the jnp oracle (the production
XLA:CPU path); Pallas timings are meaningful only on TPU — interpret
mode is correctness-only. Both facts are recorded in the CSV note."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import ops, ref

from .common import csv_line, emit, timeit

K = 10          # top-k width of every merge case (paper default)
LEAF_M = 64     # rows per visited leaf in the merge widths


def run(scale: str = "default", out_dir=None) -> List[dict]:
    rng = np.random.default_rng(0)
    sizes = {"small": (64, 2048), "default": (128, 8192),
             "large": (256, 32768)}[scale]
    b, m = sizes
    n = 256
    q = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    lo = jnp.asarray(rng.normal(size=(m, 32)) - 1, jnp.float32)
    hi = lo + 0.5
    qs = jnp.asarray(rng.normal(size=(b, 32)), jnp.float32)
    w = jnp.ones((32,), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (m, 16)), jnp.int32)
    lut = jnp.asarray(rng.uniform(size=(16, 256)), jnp.float32)
    luts = jnp.asarray(rng.uniform(size=(b, 16, 256)), jnp.float32)
    codes_coop = jnp.asarray(
        rng.integers(0, 256, (b * LEAF_M, 16)), jnp.int32)

    # merge operands at the refinement loop's real widths: the solo
    # candidate block is k + V*M per lane; the cooperative block is
    # k + B*V*M (every lane scores the whole pool). Pool ids are
    # lane-invariant, exactly like the share_gathers call sites.
    solo_w = LEAF_M
    coop_w = b * LEAF_M
    d_solo = jnp.asarray(rng.uniform(size=(b, solo_w)), jnp.float32)
    i_solo = jnp.asarray(
        rng.integers(0, 4 * m, (b, solo_w)), jnp.int32)
    d_coop = jnp.asarray(rng.uniform(size=(b, coop_w)), jnp.float32)
    i_coop1 = jnp.asarray(rng.permutation(4 * coop_w)[:coop_w],
                          jnp.int32)
    i_coop2 = jnp.broadcast_to(i_coop1[None], (b, coop_w))
    top_d = jnp.sort(jnp.asarray(rng.uniform(size=(b, K)), jnp.float32),
                     axis=1)
    top_i = jnp.asarray(10 * coop_w + np.arange(b * K).reshape(b, K),
                        jnp.int32)

    # every case is a (fn, operands) pair jitted with the operands as
    # RUNTIME arguments — closing over device arrays would inline them
    # as constants and XLA constant-folds whole sorts away (the ref
    # merge baselines then time as ~0 after a 40s+ compile)
    cases = {
        "paa": (lambda a: ops.paa(a, 16), (x,)),
        "box_mindist": (ops.box_mindist, (qs, lo, hi, w)),
        "l2": (ops.l2, (q, x)),
        "l2_topk": (lambda a, c: ops.l2_topk(a, c, K), (q, x)),
        "pq_adc": (ops.pq_adc, (codes, lut)),
        "pq_adc_batch": (ops.pq_adc_batch, (codes, luts)),
        # fused cooperative pq selection vs its full-materialization
        # oracle, at the real cooperative pool width k + B*V*M
        "pq_adc_select": (
            lambda c, l, i: ops.pq_adc_select(c, l, i, 2 * K),
            (codes_coop, luts, i_coop1)),
        "pq_adc_select_materialize_ref": (
            lambda c, l, i: ref.ref_pq_adc_select(c, l, i, 2 * K),
            (codes_coop, luts, i_coop1)),
        "topk_merge": (ops.topk_merge, (d_solo, i_solo, top_d, top_i)),
        "topk_merge_sort_ref": (ref.ref_topk_merge,
                                (d_solo, i_solo, top_d, top_i)),
        "topk_merge_unique_coop": (ops.topk_merge_unique,
                                   (d_coop, i_coop1, top_d, top_i)),
        "topk_merge_unique_sort_ref_coop":
            (ref.ref_topk_merge_unique, (d_coop, i_coop2, top_d, top_i)),
    }
    widths = {
        "pq_adc_batch": f"b={b};m_rows={m};pq_m=16",
        "pq_adc_select": f"b={b};pool={coop_w};pq_m=16;kk={2 * K}",
        "pq_adc_select_materialize_ref":
            f"b={b};pool={coop_w};pq_m=16;kk={2 * K}",
        "topk_merge": f"b={b};width=k+{solo_w}",
        "topk_merge_sort_ref": f"b={b};width=k+{solo_w}",
        "topk_merge_unique_coop": f"b={b};width=k+{coop_w}",
        "topk_merge_unique_sort_ref_coop": f"b={b};width=k+{coop_w}",
    }
    rows: List[dict] = []
    timed = {}
    for name, (fn, operands) in cases.items():
        jitted = jax.jit(fn)
        # default-arg binding: the thunk must close over THIS
        # iteration's jitted/operands, not the loop variables (B023)
        sec = timeit(lambda jf=jitted, args=operands: jf(*args),
                     repeats=5)
        timed[name] = sec
        rows.append({"bench": "kernels", "kernel": name,
                     "us_per_call": sec * 1e6,
                     "note": "XLA:CPU oracle path; Pallas validated in "
                             "interpret mode (tests/test_kernels.py)"})
        print(csv_line(f"kernel/{name}", sec * 1e6,
                       widths.get(name, f"b={b};m={m};n={n}")))
    # selection-vs-full-sort speedups (ISSUE 3 + ISSUE 5 acceptance)
    for new, old in (("topk_merge", "topk_merge_sort_ref"),
                     ("topk_merge_unique_coop",
                      "topk_merge_unique_sort_ref_coop"),
                     ("pq_adc_select", "pq_adc_select_materialize_ref")):
        speedup = timed[old] / timed[new]
        rows.append({"bench": "kernels", "kernel": f"{new}_speedup",
                     "speedup_vs_full_sort": speedup,
                     "us_new": timed[new] * 1e6,
                     "us_old": timed[old] * 1e6})
        print(csv_line(f"kernel/{new}_speedup", timed[new] * 1e6,
                       f"x{speedup:.1f}_vs_full_sort"))
    rows.append(_pq_fused_memory_row(codes_coop, luts, i_coop1, b,
                                     coop_w))
    rows.append(_obs_overhead_row(d_solo, i_solo, top_d, top_i))
    emit(rows, out_dir, "bench_kernels")
    return rows


def _obs_overhead_row(d_solo, i_solo, top_d, top_i) -> dict:
    """PR 6 acceptance: tracing DISABLED must cost < 5% on the bench
    hot path. Times the same jitted merge — the cheapest per-call op
    of the refinement loop, i.e. the worst case for fixed wrapper
    overhead — bare vs under a disabled ``obs.span``, whose cost is
    one module-global flag check + an empty ``with`` block."""
    assert not obs.enabled(), "benchmarks must run with tracing off"
    jm = jax.jit(ops.topk_merge)

    def plain():
        return jm(d_solo, i_solo, top_d, top_i)

    def spanned():
        with obs.span("bench.noop"):
            return jm(d_solo, i_solo, top_d, top_i)

    t_plain = timeit(plain, repeats=15, warmup=3)
    t_span = timeit(spanned, repeats=15, warmup=3)
    frac = max(0.0, t_span / t_plain - 1.0)
    row = {"bench": "kernels", "kernel": "obs_span_disabled_overhead",
           "overhead_frac": round(frac, 4),
           "us_plain": round(t_plain * 1e6, 2),
           "us_spanned": round(t_span * 1e6, 2),
           "threshold_frac": 0.05}
    print(csv_line("kernel/obs_span_disabled_overhead", t_span * 1e6,
                   f"overhead_frac={frac:.4f}"))
    return row


def _pq_fused_memory_row(codes_coop, luts, ids, b: int,
                         coop_w: int) -> dict:
    """The ISSUE 5 peak-memory assertion, run as part of the bench so
    the snapshot gate catches a regression to materializing: lower the
    fused kernel (interpret on CPU — the same tiling the TPU path
    uses) and the full-materialization oracle over identical
    cooperative-width operands, assert the [B, R] ADC distance matrix
    appears ONLY in the oracle's optimized HLO, and report both
    compiled temp footprints."""
    kk = 2 * K
    fused = jax.jit(lambda c, l, i: ops.pq_adc_select(
        c, l, i, kk, force_pallas=True))
    mat = jax.jit(lambda c, l, i: ref.ref_pq_adc_select(c, l, i, kk))
    fc = fused.lower(codes_coop, luts, ids).compile()
    mc = mat.lower(codes_coop, luts, ids).compile()
    # HLO shape-signature check at a FIXED pool width chosen so the
    # [B, R] matrix shape cannot collide with any legitimate operand
    # shape (at some bench scales R == m*K, the flattened-LUT width)
    rng = np.random.default_rng(1)
    b_chk, r_chk = 16, 768
    codes_chk = jnp.asarray(rng.integers(0, 256, (r_chk, 16)),
                            jnp.int32)
    luts_chk = jnp.asarray(rng.uniform(size=(b_chk, 16, 256)),
                           jnp.float32)
    ids_chk = jnp.asarray(np.arange(r_chk), jnp.int32)
    ftxt = fused.lower(codes_chk, luts_chk, ids_chk).compile().as_text()
    mtxt = mat.lower(codes_chk, luts_chk, ids_chk).compile().as_text()
    sigs = {f"f32[{b_chk},{r_chk}]", f"f32[128,{r_chk}]"}  # 128: lane pad
    assert not any(s in ftxt for s in sigs), (
        "fused pq_adc_select materializes the [B, R] ADC matrix")
    assert f"f32[{b_chk},{r_chk}]" in mtxt, (
        "materializing baseline no longer materializes — assertion "
        "lost its teeth; update the bench")
    row = {"bench": "kernels", "kernel": "pq_adc_select_memory",
           "materializes_full_matrix": False,
           "full_matrix_bytes_avoided": 4 * b * coop_w,
           "temp_bytes_fused_interpret":
               int(fc.memory_analysis().temp_size_in_bytes),
           "temp_bytes_materializing":
               int(mc.memory_analysis().temp_size_in_bytes)}
    print(csv_line("kernel/pq_adc_select_memory",
                   row["full_matrix_bytes_avoided"],
                   "full_matrix_bytes_avoided"))
    return row
