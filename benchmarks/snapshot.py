"""Perf-trajectory snapshot: one compact JSON at the repo root per PR.

``python -m benchmarks.run --snapshot`` writes ``SNAPSHOT_NAME``
(override with ``--out``) with the currencies of the serving hot path
at the default bench scale — kernel µs (selection merges vs their
full-sort baselines, and since PR 5 the fused pq_adc_select vs its
materializing oracle plus the [B, R]-never-materialized memory
check), on-disk bytes-read, in-memory queries/s, and since PR 4 the
out-of-core serving rows: engine queries/s over spill-built shards
and the Scheduler-driven deadline-mixed retrieval front, now with
per-request serve-latency DISTRIBUTIONS (p50/p95/p99 via the
repro.obs log-bucketed histograms) and the tracing-disabled overhead
row, and since PR 10 the streaming-ingest freshness row (insert ->
first-retrievable lag through the ServeFront write lane,
docs/INGEST.md) — so later PRs can diff the perf trajectory without
rerunning whole suites.
``--smoke`` compiles and runs every path once at the small scale
without writing the file (the scripts/verify.sh regression gate: a
snapshot that stops compiling fails verify before it rots).
``benchmarks/compare.py`` diffs a fresh snapshot against the
committed baseline with per-metric tolerances (the CI bench-compare
job).
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import IndexSpec, StoreSpec
from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.core.guarantees import Guarantee
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree
from repro.serve.batching import Request, Scheduler
from repro.store import DeviceLeafCache

from . import bench_kernels
from .common import dataset, timeit

SNAPSHOT_NAME = "BENCH_pr10.json"


def _repo_root_path(name: str = None) -> str:
    return os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..",
                     name or SNAPSHOT_NAME))


def collect(scale: str = "default", smoke: bool = False) -> dict:
    repeats = 1 if smoke else 3
    data, q, _bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k = p["k"]

    # --- kernel µs + the selection-vs-full-sort speedups ---
    krows = bench_kernels.run(scale, out_dir=None)
    kernels_us = {r["kernel"]: round(r["us_per_call"], 1)
                  for r in krows if "us_per_call" in r}
    speedups = {r["kernel"]: round(r["speedup_vs_full_sort"], 2)
                for r in krows if "speedup_vs_full_sort" in r}
    pq_mem = next(
        ({k: v for k, v in r.items()
          if k not in ("bench", "kernel")}
         for r in krows if r.get("kernel") == "pq_adc_select_memory"),
        None)
    obs_overhead = next(
        ({k: v for k, v in r.items()
          if k not in ("bench", "kernel")}
         for r in krows
         if r.get("kernel") == "obs_span_disabled_overhead"),
        None)

    # --- in-memory queries/s (the paper's best tree, eps=1) ---
    idx = dstree.build(data, leaf_cap=256)

    def qfn():
        return S.search(idx, qj, k, Guarantee(delta=0.99, epsilon=1.0))

    sec = timeit(qfn, repeats=repeats)
    qps = len(q) / sec

    # --- on-disk bytes-read (f32 store, solo vs cooperative) ---
    disk = {}
    with tempfile.TemporaryDirectory() as tmp:
        store = FrozenIndex.load(idx.save(os.path.join(tmp, "f32")),
                                 resident="summaries")
        cap = max(store.num_leaves // 8, qj.shape[0])
        for share in (False, True):
            cache = DeviceLeafCache(store, cap)
            t0 = time.perf_counter()
            out = S.search_ooc(store, qj, k,
                               Guarantee(delta=0.99, epsilon=1.0),
                               cache=cache, share_gathers=share)
            jax.block_until_ready(out.result.dists)
            tag = "coop" if share else "solo"
            disk[f"bytes_read_cold_{tag}"] = out.stats["bytes_read"]
            disk[f"t_cold_s_{tag}"] = round(time.perf_counter() - t0, 4)
        disk["dataset_bytes"] = out.stats["dataset_bytes"]

    # --- out-of-core serving: engine over spilled shards + the
    #     Scheduler-driven deadline-mixed retrieval front ---
    engine_ooc = {}
    serve = {}
    with tempfile.TemporaryDirectory() as tmp:
        mesh = jax.make_mesh((1,), ("data",))
        eng = DistributedEngine(mesh, method="dstree")
        eng.build(data, index=IndexSpec("dstree", leaf_cap=256),
                  store=StoreSpec(spill_dir=os.path.join(tmp, "sp"),
                                  codec="bf16", keep_resident=False))
        g = Guarantee(epsilon=1.0)
        eng.query(qj, k, g)  # warm caches + compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            res = eng.query(qj, k, g)
            jax.block_until_ready(res.dists)
        dt = (time.perf_counter() - t0) / repeats
        engine_ooc = {
            "codec": "bf16", "epsilon": 1.0,
            "queries_per_s": round(len(q) / dt, 1),
            "bytes_read_warm": res.stats["bytes_read"],
            "shards": len(eng.shard_dirs),
        }

        deadlines = [None, 40.0, 20.0, 5.0] * (len(q) // 4 + 1)
        reqs = [Request(uid=i, prompt=np.zeros(4, np.int32),
                        deadline_ms=deadlines[i], series=q[i])
                for i in range(len(q))]
        sched = Scheduler()
        sched.run_retrieval(eng, reqs, k)  # warm per-group shapes
        # per-request retrieval-latency distribution: every repeat's
        # per-uid retrieval_ms lands in a private log-bucketed
        # histogram (repro.obs quantile extraction — the serving
        # stack's own p50/p95/p99 machinery, not numpy over a list)
        lat_hist = obs.Histogram("serve.retrieval_ms", ())
        t0 = time.perf_counter()
        for _ in range(repeats):
            out_r = sched.run_retrieval(eng, reqs, k)
            for v in out_r.values():
                lat_hist.record(v["retrieval_ms"])
        dt = (time.perf_counter() - t0) / repeats
        kinds = sorted({v["kind"] for v in out_r.values()})
        qn = lat_hist.quantiles()
        serve = {
            "requests_per_s": round(len(reqs) / dt, 1),
            "deadline_mix_kinds": kinds,
            "latency_ms": {key: round(val, 3)
                           for key, val in qn.items()},
        }

        # --- the latency-vs-load curve: static barrier front vs the
        #     continuous-batching front over the SAME warm engine ---
        from . import bench_serve_load
        serve_load = bench_serve_load.run(scale, smoke=smoke,
                                          engine=eng)
        # freshness is its own top-level section (the streaming-ingest
        # headline: insert -> first-retrievable lag through the write
        # lane, docs/INGEST.md) so compare.py can gate it
        # independently of the latency-vs-load curve
        freshness = serve_load.pop("freshness", None)

    return {
        "snapshot": SNAPSHOT_NAME,
        "scale": scale,
        "backend": jax.default_backend(),
        "kernels_us": kernels_us,
        "merge_speedup_vs_full_sort": speedups,
        "pq_fused_memory": pq_mem,
        "query_memory": {
            "method": "dstree", "epsilon": 1.0, "delta": 0.99,
            "queries_per_s": round(qps, 1),
            "us_per_query": round(sec / len(q) * 1e6, 1),
        },
        "query_disk": disk,
        "engine_ooc": engine_ooc,
        "serve": serve,
        "serve_load": serve_load,
        "freshness": freshness,
        "obs_overhead": obs_overhead,
    }


def run_snapshot(scale: str = "default", smoke: bool = False,
                 out_path: Optional[str] = None) -> dict:
    snap = collect(scale=scale, smoke=smoke)
    if smoke:
        print("# snapshot smoke OK (nothing written)")
        return snap
    path = out_path or _repo_root_path()
    snap["snapshot"] = os.path.basename(path)
    with open(path, "w") as f:
        json.dump(snap, f, indent=1)
    print(f"# snapshot written to {path}")
    return snap
