"""Paper Figure 8: accuracy and efficiency vs delta and epsilon —
reproduces C2 (epsilon buys orders of magnitude, accuracy plateaus) and
C3 (the delta stop with histogram r_delta is largely ineffective)."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.indexes import dstree, isax
from repro.core.metrics import workload_metrics

from .common import csv_line, dataset, emit, timeit


def run(scale: str = "default", out_dir=None) -> List[dict]:
    data, q, bf, p = dataset(scale)
    qj = jnp.asarray(q)
    k = p["k"]
    rows: List[dict] = []
    built = {
        "dstree": dstree.build(data, leaf_cap=256),
        "isax2+": isax.build(data, leaf_cap=256),
    }
    # (a-c) epsilon sweep at delta=1
    for name, idx in built.items():
        for eps in (0.0, 0.5, 1.0, 2.0, 3.0, 5.0, 10.0):
            fn = lambda idx=idx, e=eps: S.search(idx, qj, k,
                                                 G.epsilon(e))
            res = fn()
            sec = timeit(fn, repeats=3)
            m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
            rows.append({"bench": "delta_epsilon", "method": name,
                         "sweep": "epsilon", "value": eps,
                         "throughput_qps": len(q) / sec, **m})
            print(csv_line(f"fig8/{name}/eps{eps}",
                           sec / len(q) * 1e6,
                           f"map={m['map']:.3f};mre={m['mre']:.4f}"))
    # (d-e) delta sweep at epsilon=0
    for name, idx in built.items():
        for delta in (0.5, 0.8, 0.9, 0.99, 1.0):
            fn = lambda idx=idx, d=delta: S.search(
                idx, qj, k, G.Guarantee(delta=d))
            res = fn()
            sec = timeit(fn, repeats=3)
            m = workload_metrics(res.ids, res.dists, bf.ids, bf.dists)
            rows.append({"bench": "delta_epsilon", "method": name,
                         "sweep": "delta", "value": delta,
                         "throughput_qps": len(q) / sec, **m})
            print(csv_line(f"fig8/{name}/delta{delta}",
                           sec / len(q) * 1e6,
                           f"map={m['map']:.3f}"))
    emit(rows, out_dir, "bench_delta_epsilon")
    return rows
