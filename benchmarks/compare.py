"""Bench-regression gate: diff a fresh snapshot against the committed
baseline (the CI ``bench-compare`` job).

    PYTHONPATH=src python -m benchmarks.compare                  # run a
        fresh --snapshot-style collection at the baseline's scale and
        diff it against the newest BENCH_pr*.json at the repo root
    PYTHONPATH=src python -m benchmarks.compare --smoke          # small
        scale (CI default: minutes, not tens of minutes)
    PYTHONPATH=src python -m benchmarks.compare --fresh f.json   # diff
        an already-collected snapshot instead of collecting one
    PYTHONPATH=src python -m benchmarks.compare --write-fresh out.json
        # also save the fresh snapshot (CI uploads it as an artifact)

Exit status: 0 when every checked metric is within tolerance, 1 on any
regression, 2 on usage/baseline errors.

Tolerance policy (docs/CI.md): CI machines are noisy and differ from
the container that wrote the baseline, so ABSOLUTE timings are held
only to loose order-of-magnitude bounds, while RATIO and STRUCTURAL
metrics — the ones a code regression actually moves — are held tight:

  ratio metrics    merge/selection speedups vs their retained
                   full-sort baselines: must keep >= RATIO_KEEP of the
                   baseline speedup (a fused kernel silently falling
                   back to the materializing path shows up here);
                   cross-scale runs use the looser
                   CROSS_SCALE_RATIO_KEEP floor — the sort references
                   grow superlinearly with scale, the fused paths
                   don't, so the ratio itself is scale-dependent.
  structural       bytes-read, dataset bytes, shard counts, the
                   pq_fused_memory no-materialization flag, the
                   serve_load degraded-tier fractions (+/- DEGRADED_TOL
                   absolute) and its continuous-beats-static headline
                   flag (baseline flag always; fresh flag at the
                   baseline's scale, where queueing — not front
                   overhead — dominates p99): tight tol, they move
                   only when the access pattern or the shedding/remap
                   policy changes. The freshness section's
                   retrievable_immediately flag (an inserted row's
                   first post-apply query returns it) is likewise
                   structural and scale-free.
  timings          us_per_call / queries_per_s / requests_per_s and
                   the serve_load per-load-point p50/p99: must not
                   degrade by more than TIME_FACTOR x.

``--smoke`` collects at the small scale, where absolute values differ
from the (default-scale) baseline by construction — so scale-dependent
metrics are SKIPPED and only scale-free ratios + flags are enforced.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

RATIO_KEEP = 0.5     # keep >= 50% of the baseline speedup
# across scales the speedups are NOT constants — the full-sort
# references grow superlinearly in pool width while the fused merges
# stay linear, so a small-scale fresh run legitimately keeps less of a
# default-scale baseline's ratio. The cross-scale floor only has to
# catch the failure it exists for: a fused kernel silently falling
# back to its materializing/full-sort path collapses the ratio to ~1x.
CROSS_SCALE_RATIO_KEEP = 0.25
TIME_FACTOR = 3.0    # absolute timings may degrade <= 3x
BYTES_TOL = 0.05     # structural byte counts move <= 5%
DEGRADED_TOL = 0.05  # degraded-tier fraction moves <= 5% ABSOLUTE

# sections this gate knows how to diff; anything else found in either
# snapshot is SKIPPED with a log line, never a crash — future PRs add
# sections without breaking older baselines (and vice versa)
KNOWN_SECTIONS = {
    "snapshot", "scale", "backend", "kernels_us",
    "merge_speedup_vs_full_sort", "pq_fused_memory", "query_memory",
    "query_disk", "engine_ooc", "serve", "serve_load", "freshness",
    "obs_overhead",
}


def newest_baseline(root: str) -> str:
    """The committed BENCH_pr<N>.json with the highest N."""
    paths = glob.glob(os.path.join(root, "BENCH_pr*.json"))
    if not paths:
        raise FileNotFoundError(f"no BENCH_pr*.json under {root}")

    def prnum(p):
        m = re.search(r"BENCH_pr(\d+)\.json$", p)
        return int(m.group(1)) if m else -1

    return max(paths, key=prnum)


def _check(name, ok, detail, failures, lines):
    mark = "ok  " if ok else "FAIL"
    lines.append(f"  [{mark}] {name}: {detail}")
    if not ok:
        failures.append(name)


def compare(base: dict, fresh: dict, *, same_scale: bool) -> tuple:
    """Diff fresh against base under the tolerance policy. Returns
    (failures, report_lines)."""
    failures: list = []
    lines: list = []

    lines.append(f"baseline={base.get('snapshot')} "
                 f"scale={base.get('scale')} | fresh scale="
                 f"{fresh.get('scale')} (same_scale={same_scale})")

    # unknown sections: log and move on (tolerate snapshots from
    # newer/older PRs on either side)
    for which, snap in (("baseline", base), ("fresh", fresh)):
        for sec in sorted(set(snap) - KNOWN_SECTIONS):
            lines.append(f"  [skip] unknown section {sec!r} in "
                         f"{which} snapshot: not compared")

    # --- ratio metrics: scale-free, enforced always ---
    bs = base.get("merge_speedup_vs_full_sort") or {}
    fs = fresh.get("merge_speedup_vs_full_sort") or {}
    for key, bval in sorted(bs.items()):
        fval = fs.get(key)
        if fval is None:
            _check(f"speedup/{key}", False, "missing in fresh run",
                   failures, lines)
            continue
        keep = RATIO_KEEP if same_scale else CROSS_SCALE_RATIO_KEEP
        need = keep * bval
        _check(f"speedup/{key}", fval >= need,
               f"{fval:.2f}x vs baseline {bval:.2f}x "
               f"(floor {need:.2f}x)", failures, lines)

    # --- structural flags: enforced always ---
    bmem = base.get("pq_fused_memory")
    fmem = fresh.get("pq_fused_memory")
    if bmem is not None:
        if fmem is None:
            _check("pq_fused_memory", False, "missing in fresh run",
                   failures, lines)
        else:
            _check("pq_fused_memory/materializes_full_matrix",
                   fmem.get("materializes_full_matrix") is False,
                   str(fmem.get("materializes_full_matrix")),
                   failures, lines)

    # --- serve_load headline flag: the COMMITTED baseline must claim
    #     the win — the continuous front beats the static barrier at
    #     the top load point (PR 9 acceptance). Checked against the
    #     baseline because it is deterministic at any collection
    #     scale; the fresh run's flag is only meaningful at the
    #     baseline's scale (at the small scale engine calls are cheap
    #     enough that front overhead, not queueing, dominates p99) so
    #     it is enforced in the same-scale section below.
    bsl = base.get("serve_load") or {}
    fsl = fresh.get("serve_load") or {}
    if bsl:
        _check("serve_load/continuous_beats_static[baseline]",
               bool((bsl.get("summary") or {})
                    .get("continuous_beats_static")),
               f"baseline summary: {bsl.get('summary')}",
               failures, lines)
        if not fsl:
            _check("serve_load", False, "missing in fresh run",
                   failures, lines)

    # --- freshness: the streaming-ingest headline (PR 10). The
    #     retrievable_immediately flag is structural and scale-free —
    #     an inserted row's FIRST post-apply query must return it at
    #     any collection scale — so it is enforced on both snapshots
    #     always; the lag quantiles are absolute timings, gated in the
    #     same-scale section below.
    bfr = base.get("freshness") or {}
    ffr = fresh.get("freshness") or {}
    if bfr:
        _check("freshness/retrievable_immediately[baseline]",
               bfr.get("retrievable_immediately") is True,
               str(bfr.get("retrievable_immediately")),
               failures, lines)
        if not ffr:
            _check("freshness", False, "missing in fresh run",
                   failures, lines)
        else:
            _check("freshness/retrievable_immediately",
                   ffr.get("retrievable_immediately") is True,
                   str(ffr.get("retrievable_immediately")),
                   failures, lines)

    if not same_scale:
        lines.append("  (scale differs: scale-dependent metrics "
                     "skipped)")
        return failures, lines

    # --- serve_load curve: per load point, per front — p50/p99 under
    #     the loose timing tolerance, degraded-tier fraction held to a
    #     tight ABSOLUTE band (it is a structural quality metric: it
    #     moves when the shedding/remap policy changes, not when the
    #     box is merely slow) ---
    if bsl and fsl:
        _check("serve_load/continuous_beats_static",
               bool((fsl.get("summary") or {})
                    .get("continuous_beats_static")),
               f"fresh summary: {fsl.get('summary')}",
               failures, lines)
        fpts = {p.get("load_factor"): p for p in fsl.get("points", [])}
        for bp in bsl.get("points", []):
            lf = bp.get("load_factor")
            fp = fpts.get(lf)
            if fp is None:
                _check(f"serve_load/x{lf}", False,
                       "load point missing in fresh run",
                       failures, lines)
                continue
            for mode in ("static", "continuous"):
                bm = bp.get(mode) or {}
                fm = fp.get(mode) or {}
                for qk in ("p50_ms", "p99_ms"):
                    bval = bm.get(qk)
                    if bval is None:
                        continue
                    fval = fm.get(qk)
                    if fval is None:
                        _check(f"serve_load/x{lf}/{mode}/{qk}", False,
                               "missing in fresh run", failures, lines)
                        continue
                    hi = bval * TIME_FACTOR
                    _check(f"serve_load/x{lf}/{mode}/{qk}",
                           fval <= hi,
                           f"{fval:.2f}ms vs baseline {bval:.2f}ms "
                           f"(ceiling {hi:.2f}ms)", failures, lines)
                bd = bm.get("degraded_frac")
                fd = fm.get("degraded_frac")
                if bd is None:
                    continue
                if fd is None:
                    _check(f"serve_load/x{lf}/{mode}/degraded_frac",
                           False, "missing in fresh run",
                           failures, lines)
                    continue
                _check(f"serve_load/x{lf}/{mode}/degraded_frac",
                       abs(fd - bd) <= DEGRADED_TOL,
                       f"{fd:.3f} vs baseline {bd:.3f} "
                       f"(tol +/-{DEGRADED_TOL})", failures, lines)

    # --- structural bytes: tight, same scale only ---
    for sec, key in (("query_disk", "bytes_read_cold_solo"),
                     ("query_disk", "bytes_read_cold_coop"),
                     ("query_disk", "dataset_bytes"),
                     ("engine_ooc", "bytes_read_warm"),
                     ("engine_ooc", "shards")):
        bval = (base.get(sec) or {}).get(key)
        fval = (fresh.get(sec) or {}).get(key)
        if bval is None:
            continue
        if fval is None:
            _check(f"{sec}/{key}", False, "missing in fresh run",
                   failures, lines)
            continue
        hi = bval * (1 + BYTES_TOL)
        _check(f"{sec}/{key}", fval <= hi,
               f"{fval} vs baseline {bval} (ceiling {hi:.0f})",
               failures, lines)

    # --- absolute timings: loose, same scale only ---
    bk = base.get("kernels_us") or {}
    fk = fresh.get("kernels_us") or {}
    for key, bval in sorted(bk.items()):
        fval = fk.get(key)
        if fval is None:
            _check(f"kernels_us/{key}", False, "missing in fresh run",
                   failures, lines)
            continue
        hi = bval * TIME_FACTOR
        _check(f"kernels_us/{key}", fval <= hi,
               f"{fval:.1f}us vs baseline {bval:.1f}us "
               f"(ceiling {hi:.1f}us)", failures, lines)
    for sec, key in (("query_memory", "queries_per_s"),
                     ("engine_ooc", "queries_per_s"),
                     ("serve", "requests_per_s")):
        bval = (base.get(sec) or {}).get(key)
        fval = (fresh.get(sec) or {}).get(key)
        if bval is None:
            continue
        if fval is None:
            _check(f"{sec}/{key}", False, "missing in fresh run",
                   failures, lines)
            continue
        lo = bval / TIME_FACTOR
        _check(f"{sec}/{key}", fval >= lo,
               f"{fval:.1f}/s vs baseline {bval:.1f}/s "
               f"(floor {lo:.1f}/s)", failures, lines)

    # --- freshness lag quantiles: absolute timings, loose, same
    #     scale only (insert -> applied / insert -> visible, ms) ---
    if bfr and ffr:
        for qk in ("applied_ms_p50", "visible_ms_p50",
                   "visible_ms_p99"):
            bval = bfr.get(qk)
            if bval is None:
                continue
            fval = ffr.get(qk)
            if fval is None:
                _check(f"freshness/{qk}", False,
                       "missing in fresh run", failures, lines)
                continue
            hi = bval * TIME_FACTOR
            _check(f"freshness/{qk}", fval <= hi,
                   f"{fval:.2f}ms vs baseline {bval:.2f}ms "
                   f"(ceiling {hi:.2f}ms)", failures, lines)

    # --- serve latency quantiles: absolute timings, loose, same
    #     scale only. p50 and p99 are gated (p95 informational: it
    #     adds no signal between the two and doubles the flake
    #     surface on a noisy CI box) ---
    blat = (base.get("serve") or {}).get("latency_ms") or {}
    flat = (fresh.get("serve") or {}).get("latency_ms") or {}
    for qk in ("p50", "p99"):
        bval = blat.get(qk)
        if bval is None:
            continue
        fval = flat.get(qk)
        if fval is None:
            _check(f"serve/latency_ms/{qk}", False,
                   "missing in fresh run", failures, lines)
            continue
        hi = bval * TIME_FACTOR
        _check(f"serve/latency_ms/{qk}", fval <= hi,
               f"{fval:.2f}ms vs baseline {bval:.2f}ms "
               f"(ceiling {hi:.2f}ms)", failures, lines)
    return failures, lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=None,
                    help="baseline snapshot JSON (default: newest "
                         "BENCH_pr*.json at the repo root)")
    ap.add_argument("--fresh", default=None,
                    help="pre-collected fresh snapshot JSON; omit to "
                         "collect one now")
    ap.add_argument("--smoke", action="store_true",
                    help="collect the fresh snapshot at the small "
                         "scale (scale-dependent metrics skipped)")
    ap.add_argument("--write-fresh", default=None,
                    help="also write the fresh snapshot JSON here "
                         "(CI artifact)")
    args = ap.parse_args()

    root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        ".."))
    try:
        base_path = args.baseline or newest_baseline(root)
        with open(base_path) as f:
            base = json.load(f)
    except (OSError, ValueError) as e:
        print(f"compare: cannot load baseline: {e}", file=sys.stderr)
        sys.exit(2)

    if args.fresh:
        try:
            with open(args.fresh) as f:
                fresh = json.load(f)
        except (OSError, ValueError) as e:
            print(f"compare: cannot load fresh snapshot: {e}",
                  file=sys.stderr)
            sys.exit(2)
    else:
        from . import snapshot
        scale = "small" if args.smoke else base.get("scale", "default")
        fresh = snapshot.collect(scale=scale, smoke=args.smoke)
        fresh["scale"] = scale
    if args.write_fresh:
        with open(args.write_fresh, "w") as f:
            json.dump(fresh, f, indent=1)
        print(f"# fresh snapshot written to {args.write_fresh}")

    same_scale = fresh.get("scale") == base.get("scale")
    failures, lines = compare(base, fresh, same_scale=same_scale)
    print(f"# bench-compare vs {os.path.basename(base_path)}")
    for ln in lines:
        print(ln)
    if failures:
        print(f"# REGRESSION: {len(failures)} metric(s) out of "
              f"tolerance: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)
    print("# bench-compare OK")


if __name__ == "__main__":
    main()
