"""Roofline table assembly (§Roofline): reads the dry-run artifacts
written by repro.launch.dryrun and prints/aggregates the three terms."""

from __future__ import annotations

import glob
import json
import os
from typing import List

from .common import csv_line, emit


def run(scale: str = "default", out_dir=None,
        dryrun_dir: str = "experiments/dryrun") -> List[dict]:
    rows: List[dict] = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        mesh = os.path.basename(os.path.dirname(path))
        if rep.get("status") != "ok":
            rows.append({"bench": "roofline", "mesh": mesh,
                         "cell": os.path.basename(path)[:-5],
                         "status": rep.get("status"),
                         "reason": rep.get("reason",
                                           rep.get("error", ""))[:200]})
            continue
        t = rep["terms_seconds"]
        dominant = rep["bottleneck"]
        rows.append({
            "bench": "roofline", "mesh": mesh,
            "cell": f"{rep['arch']}__{rep['shape']}",
            "status": "ok",
            "compute_s": t["compute"], "memory_s": t["memory"],
            "collective_s": t["collective"], "bottleneck": dominant,
            "useful_flops_ratio": rep["useful_flops_ratio"],
            "hbm_frac": rep.get("memory_analysis", {}).get("hbm_frac"),
        })
        print(csv_line(
            f"roofline/{mesh}/{rep['arch']}/{rep['shape']}",
            t[dominant] * 1e6,
            f"bottleneck={dominant};useful="
            f"{rep['useful_flops_ratio']:.2f}"))
    if not rows:
        print(csv_line("roofline/none", 0.0,
                       "run repro.launch.dryrun first"))
    emit(rows, out_dir, "bench_roofline")
    return rows
