"""Out-of-core search walkthrough: the paper's on-disk regime.

Build an index, persist it as a leaf-contiguous store artifact, reload
ONLY the summaries onto the device, and answer queries while the raw
series stream from disk through a fixed-size device leaf cache fed by
an async prefetcher. The answers are bit-identical to the in-memory
path for every guarantee — only residency changes.

    PYTHONPATH=src python examples/ooc_search.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guarantees as G
from repro.core import search as S
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree
from repro.data import queries, randomwalk
from repro.store import DeviceLeafCache

N, LEN, K = 8192, 256, 10

print(f"1. build: dstree over {N} random-walk series of length {LEN}")
data = randomwalk.generate(seed=11, n_series=N, series_len=LEN)
q = queries.noisy_queries(data, 16)
qj = jnp.asarray(q)
idx = dstree.build(data, leaf_cap=256)
print(f"   {idx.num_leaves} leaves, raw payload "
      f"{np.asarray(idx.data).nbytes / 1e6:.1f} MB on device")

with tempfile.TemporaryDirectory() as tmp:
    store_dir = os.path.join(tmp, "dstree_store")
    print("2. save: FrozenIndex.save -> leaf-contiguous data.bin + "
          "sidecar")
    idx.save(store_dir)
    for f in sorted(os.listdir(store_dir)):
        sz = os.path.getsize(os.path.join(store_dir, f))
        print(f"   {f:12s} {sz / 1e6:8.3f} MB")

    print("3. load resident='summaries': raw data STAYS on disk")
    store = FrozenIndex.load(store_dir, resident="summaries")
    print("   device-resident placeholder rows: "
          f"{store.resident.data.shape[0]} (filter state only)")

    cap = max(store.num_leaves // 4, 16)
    print(f"4. search_ooc with a {cap}-leaf device cache "
          f"({cap}/{store.num_leaves} of the payload resident at once)")
    cache = DeviceLeafCache(store, cap)

    t0 = time.perf_counter()
    cold = S.search_ooc(store, qj, K, G.epsilon(1.0), cache=cache)
    jax.block_until_ready(cold.result.dists)
    t_cold = time.perf_counter() - t0
    cache.reset_counters()
    t0 = time.perf_counter()
    warm = S.search_ooc(store, qj, K, G.epsilon(1.0), cache=cache)
    jax.block_until_ready(warm.result.dists)
    t_warm = time.perf_counter() - t0

    ref = S.search(idx, qj, K, G.epsilon(1.0))
    same = bool(np.array_equal(np.asarray(ref.ids),
                               np.asarray(cold.result.ids)))
    print(f"   identical top-{K} to the in-memory search: {same}")
    for tag, out, t in (("cold", cold, t_cold), ("warm", warm, t_warm)):
        s = out.stats
        print(f"   {tag}: {t * 1e3:7.1f} ms  "
              f"disk={s['bytes_read'] / 1e6:6.2f} MB  "
              f"h2d={s['bytes_h2d'] / 1e6:6.2f} MB  "
              f"hit_rate={s['hit_rate']:.2f}  "
              f"prefetch_staged={s['prefetch_hits']}/{s['misses']}")

    print("5. frontier-aware prefetch depth: the host frontier hands "
          "the prefetcher the next depth x visit_batch windows")
    for depth in (1, 4):
        dcache = DeviceLeafCache(store, cap)
        out = S.search_ooc(store, qj, K, G.epsilon(1.0), cache=dcache,
                           prefetch_depth=depth)
        jax.block_until_ready(out.result.dists)
        s = out.stats
        print(f"   depth={depth}: "
              f"prefetch_staged={s['prefetch_hits']}/{s['misses']}  "
              f"disk={s['bytes_read'] / 1e6:6.2f} MB (speculation "
              "past a lane's stop is bounded by depth windows)")

    print("6. leaf codecs (store format v2) x cooperative scoring: "
          "the two bytes-read levers")
    f32_read = None
    for codec in ("f32", "bf16", "pq"):
        cdir = os.path.join(tmp, f"store_{codec}")
        idx.save(cdir, codec=codec)
        cstore = FrozenIndex.load(cdir, resident="summaries")
        for share in (False, True):
            ccache = DeviceLeafCache(cstore, cap)
            out = S.search_ooc(cstore, qj, K, G.epsilon(1.0), cache=ccache,
                               share_gathers=share)
            jax.block_until_ready(out.result.dists)
            read = out.stats["bytes_read"]
            if f32_read is None:
                f32_read = read
            ok = bool(np.array_equal(np.asarray(ref.ids),
                                     np.asarray(out.result.ids)))
            print(f"   codec={codec:4s} share_gathers={int(share)}  "
                  f"disk={read / 1e6:6.2f} MB "
                  f"({read / f32_read:5.3f}x of f32)  "
                  f"same top-{K}: {ok}")

print("\nthe warm pass reads fewer bytes at a higher hit rate — the "
      "cache + prefetcher turn the paper's on-disk regime into a "
      "served workload instead of a proxy metric; bf16/pq leaf codecs "
      "and cooperative (share_gathers) scoring then cut the bytes each "
      "query pays, which is exactly the currency the paper's on-disk "
      "argument is about.")
