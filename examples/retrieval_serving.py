"""End-to-end serving driver: batched LM decoding + the paper's search
engine as a first-class retrieval feature.

Pipeline: a (reduced) gemma2-family model embeds data series by mean
final hidden state -> the embedding collection is indexed with DSTree
-> requests arrive with deadlines -> the scheduler buckets them, the
model decodes, and each request's retrieval runs under the guarantee
its deadline affords (epsilon-guaranteed when relaxed, ng(nprobe) when
tight — the paper's taxonomy as graceful degradation).

    PYTHONPATH=src python examples/retrieval_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import search as S
from repro.core.indexes import dstree
from repro.core.metrics import workload_metrics
from repro.data import randomwalk
from repro.models import model as M
from repro.models.params import initialize
from repro.serve.batching import (Request, Scheduler,
                                  guarantee_for_deadline)
from repro.serve.serve_step import generate

KEY = jax.random.PRNGKey(0)

# --- 1. a small LM and a series collection it embeds ---
cfg = get_smoke_config("gemma2-2b")
params = initialize(M.model_specs(cfg), KEY)
N, LEN = 4096, 128
series = randomwalk.generate(7, N, LEN)


def embed(series_batch: np.ndarray) -> np.ndarray:
    """Mean final hidden state over tokenized (discretized) series."""
    toks = jnp.clip(
        ((jnp.asarray(series_batch) + 3) / 6 * (cfg.vocab_size - 1)),
        0, cfg.vocab_size - 1).astype(jnp.int32)
    from repro.models.model import _backbone

    x, _, _ = _backbone(params, toks, cfg)
    return np.asarray(x.mean(axis=1), np.float32)


print("embedding collection ...")
emb = np.concatenate([embed(series[i:i + 512])
                      for i in range(0, N, 512)])
emb = (emb - emb.mean(0)) / (emb.std(0) + 1e-9)

print("building DSTree over embeddings ...")
idx = dstree.build(emb, n_segments=8, leaf_cap=128)

# --- 2. batched decode serving with deadline-aware retrieval ---
sched = Scheduler(max_batch=4)
rng = np.random.default_rng(0)
deadlines = [None, 40.0, 5.0, None, 2.0, 20.0, None, 1.0]
for uid, dl in enumerate(deadlines):
    prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(5, 12))
    sched.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                         max_new_tokens=8, deadline_ms=dl))

qi = rng.choice(N, len(deadlines), replace=False)
queries = jnp.asarray(emb[qi] + 0.05 * rng.normal(size=emb[qi].shape)
                      .astype(np.float32))
truth = S.brute_force(queries, jnp.asarray(emb), 5)

print(f"\n{'uid':>3s} {'deadline':>9s} {'guarantee':>14s} "
      f"{'recall@5':>9s} {'gen tokens':>24s}")
done = 0
while True:
    nb = sched.next_batch()
    if nb is None:
        break
    bucket, reqs = nb
    prompts = jnp.asarray(sched.pad_prompts(bucket, reqs))
    toks, _ = generate(params, cfg, prompts,
                       max(r.max_new_tokens for r in reqs))
    for i, r in enumerate(reqs):
        g = guarantee_for_deadline(r.deadline_ms)
        res = S.search_with_guarantee(idx, queries[r.uid:r.uid + 1], 5, g)
        m = workload_metrics(res.ids, res.dists,
                             truth.ids[r.uid:r.uid + 1],
                             truth.dists[r.uid:r.uid + 1])
        tok_str = ",".join(str(int(t))
                           for t in toks[i, :6])
        dl = "-" if r.deadline_ms is None else f"{r.deadline_ms:.0f}ms"
        print(f"{r.uid:3d} {dl:>9s} {g.kind:>14s} "
              f"{m['avg_recall']:9.2f} {tok_str:>24s}")
        done += 1
print(f"\nserved {done} requests — tight deadlines degraded to "
      f"ng(nprobe) retrieval instead of dropping (paper Fig. 8: the "
      f"first bsf is already near-exact).")
