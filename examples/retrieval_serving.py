"""End-to-end serving driver: batched LM decoding + the paper's search
engine as a first-class retrieval feature — served OUT-OF-CORE.

Pipeline: a (reduced) gemma2-family model embeds data series by mean
final hidden state -> the embedding collection is built into a
DistributedEngine and SPILLED to disk (``build(spill_dir=...,
keep_resident=False)``: no HBM-resident payload at all) -> requests
arrive with deadlines and a retrieval query -> ``serve_requests``
drives the Scheduler's retrieval front, which partitions every drained
batch by its deadline-mapped guarantee (epsilon -> delta-epsilon ->
ng(nprobe) graceful degradation) and issues one ``engine.query`` per
group; the engine detects the spill-built shards and runs the
host-driven out-of-core refinement loop per shard (the same shared
core the in-memory search traces — core/refine.py).

    PYTHONPATH=src python examples/retrieval_serving.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.core.metrics import workload_metrics
from repro.data import randomwalk
from repro.launch.serve import serve_requests
from repro.models import model as M
from repro.models.params import initialize
from repro.serve.batching import Request

KEY = jax.random.PRNGKey(0)

# --- 1. a small LM and a series collection it embeds ---
cfg = get_smoke_config("gemma2-2b")
params = initialize(M.model_specs(cfg), KEY)
N, LEN = 4096, 128
series = randomwalk.generate(7, N, LEN)


def embed(series_batch: np.ndarray) -> np.ndarray:
    """Mean final hidden state over tokenized (discretized) series."""
    toks = jnp.clip(
        ((jnp.asarray(series_batch) + 3) / 6 * (cfg.vocab_size - 1)),
        0, cfg.vocab_size - 1).astype(jnp.int32)
    from repro.models.model import _backbone

    x, _, _ = _backbone(params, toks, cfg)
    return np.asarray(x.mean(axis=1), np.float32)


print("embedding collection ...")
emb = np.concatenate([embed(series[i:i + 512])
                      for i in range(0, N, 512)])
emb = (emb - emb.mean(0)) / (emb.std(0) + 1e-9)

rng = np.random.default_rng(0)
deadlines = [None, 40.0, 5.0, None, 2.0, 20.0, None, 1.0]
qi = rng.choice(N, len(deadlines), replace=False)
queries = (emb[qi] + 0.05 * rng.normal(size=emb[qi].shape)
           ).astype(np.float32)
truth = S.brute_force(jnp.asarray(queries), jnp.asarray(emb), 5)

with tempfile.TemporaryDirectory() as tmp:
    print("building + spilling engine shards (keep_resident=False: "
          "the payload never lives in HBM) ...")
    mesh = jax.make_mesh((1,), ("data",))
    engine = DistributedEngine(mesh, method="dstree").build(
        emb, n_segments=8, leaf_cap=128,
        spill_dir=os.path.join(tmp, "spill"), codec="bf16",
        keep_resident=False)
    for d in engine.shard_dirs:
        sz = sum(os.path.getsize(os.path.join(d, f))
                 for f in os.listdir(d))
        print(f"   {os.path.basename(d)}: {sz / 1e6:.2f} MB on disk")

    # --- 2. deadline-aware requests through the real serving front ---
    requests = [
        Request(uid=uid,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(5, 12)
                                    ).astype(np.int32),
                max_new_tokens=8, deadline_ms=dl, series=queries[uid])
        for uid, dl in enumerate(deadlines)
    ]
    results = serve_requests(params, cfg, requests, engine=engine,
                             retrieval_k=5, max_batch=4)

    print(f"\n{'uid':>3s} {'deadline':>9s} {'guarantee':>14s} "
          f"{'recall@5':>9s} {'gen tokens':>24s}")
    for uid in sorted(results):
        r = results[uid]
        ret = r["retrieval"]
        m = workload_metrics(
            jnp.asarray(ret["ids"][None]),
            jnp.asarray(ret["dists"][None]),
            truth.ids[uid:uid + 1], truth.dists[uid:uid + 1])
        tok_str = ",".join(str(int(t)) for t in r["tokens"][:6])
        dl = deadlines[uid]
        dls = "-" if dl is None else f"{dl:.0f}ms"
        print(f"{uid:3d} {dls:>9s} {ret['kind']:>14s} "
              f"{m['avg_recall']:9.2f} {tok_str:>24s}")

    # per-query I/O accounting rides each result entry's stats
    # (QueryResult.stats) — summed here over every request's own group
    mb = sum(r["retrieval"]["stats"]["bytes_read"]
             for r in results.values()
             if r.get("retrieval", {}).get("stats") is not None) / 1e6
    print(f"\nserved {len(results)} requests out-of-core (groups "
          f"read {mb:.2f} MB from disk) — tight deadlines degraded "
          "through delta-epsilon to ng(nprobe) retrieval instead of "
          "dropping (paper Fig. 8: the first bsf is already "
          "near-exact).")
