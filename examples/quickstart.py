"""Quickstart: the paper's pipeline in 60 lines.

Generate a random-walk collection (paper §4.1), build the three data
series indexes, answer 100-NN queries across the full guarantee
taxonomy, and evaluate with the paper's measures.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import search as S
from repro.core.guarantees import delta_epsilon, epsilon, exact, ng
from repro.core.indexes import dstree, isax, vafile
from repro.core.metrics import workload_metrics
from repro.data import queries, randomwalk

N, LEN, K = 8192, 256, 100

print(f"generating {N} random-walk series of length {LEN} ...")
data = randomwalk.generate(seed=11, n_series=N, series_len=LEN)
q = queries.noisy_queries(data, 16)
qj = jnp.asarray(q)
truth = S.brute_force(qj, jnp.asarray(data), K)

indexes = {
    "isax2+": (isax.build(data, leaf_cap=256), 1),
    "dstree": (dstree.build(data, leaf_cap=256), 1),
    "va+file": (vafile.build(data), 64),
}

guarantees = {
    "exact": exact(),
    "eps=1": epsilon(1.0),
    "d=.99,eps=1": delta_epsilon(0.99, 1.0),
    "ng(nprobe=4)": ng(4),
}

hdr = f"{'index':9s} {'guarantee':13s} {'MAP':>6s} {'recall':>7s} " \
      f"{'MRE':>7s} {'leaves':>7s} {'%data':>7s}"
print(hdr)
print("-" * len(hdr))
for iname, (idx, vb) in indexes.items():
    for gname, g in guarantees.items():
        res = S.search_with_guarantee(idx, qj, K, g, visit_batch=vb)
        m = workload_metrics(res.ids, res.dists, truth.ids, truth.dists)
        print(f"{iname:9s} {gname:13s} {m['map']:6.3f} "
              f"{m['avg_recall']:7.3f} {m['mre']:7.4f} "
              f"{float(res.leaves_visited.mean()):7.0f} "
              f"{100 * float(res.rows_scanned.mean()) / N:6.2f}%")
print("\nexact MAP must be 1.000; eps rows show the paper's headline "
      "result: near-exact answers at a fraction of the data accessed.")
