"""End-to-end training driver: a Minitron-family LM trained for a few
hundred steps with full production plumbing (sharded-capable train
step, AdamW, checkpoint/restart, fault injection, stateless data).

Default is a CPU-sized model (~11M params, 300 steps in minutes);
``--full`` selects a ~100M-parameter config (same code path — run it on
real accelerators).

    PYTHONPATH=src python examples/train_embedder.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.launch.train import fit
from repro.train.fault import FaultInjector


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (accelerator recommended)")
    ap.add_argument("--inject-fault", action="store_true",
                    help="kill the step function mid-run to demo "
                         "checkpoint/restart")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (default: fresh tmp dir; pass "
                         "a path to demonstrate resume)")
    args = ap.parse_args()

    cfg = get_smoke_config("minitron-8b")
    if args.full:
        cfg = dataclasses.replace(
            cfg, num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32768)
    print(f"training {cfg.name} variant: {cfg.param_count():,} params")

    injector = FaultInjector(fail_at=[args.steps // 2]) \
        if args.inject_fault else None
    ckpt_dir = args.ckpt
    if ckpt_dir is None:
        import tempfile

        ckpt_dir = tempfile.mkdtemp(prefix="hydra_embedder_")
    out = fit(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=ckpt_dir, ckpt_every=max(10, args.steps // 6),
              injector=injector)
    losses = out["losses"]
    print(f"step   0: loss {losses[0]:.4f}")
    print(f"step {len(losses) - 1:3d}: loss {losses[-1]:.4f}")
    print(f"restarts: {out['restarts']}  stragglers: "
          f"{out['stragglers']}")
    assert losses[-1] < losses[0], "loss should decrease"
    print("ok — loss decreased; checkpoints in", ckpt_dir)


if __name__ == "__main__":
    main()
