import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""Distributed search engine demo on an 8-device (4x2) mesh.

The collection is range-sharded over the 'data' axis; each shard runs
the batched Algorithm 2 locally under shard_map and per-shard top-k
rows merge with an all-gather — exact answers match brute force, and
guarantees transfer (DESIGN.md §5.3).

    python examples/distributed_search.py        # sets XLA_FLAGS itself
"""

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import search as S  # noqa: E402
from repro.core import IndexSpec  # noqa: E402
from repro.core.engine import DistributedEngine  # noqa: E402
from repro.core.guarantees import Guarantee  # noqa: E402
from repro.core.metrics import workload_metrics  # noqa: E402
from repro.data import queries, randomwalk  # noqa: E402

print("devices:", len(jax.devices()))
mesh = jax.make_mesh((4, 2), ("data", "model"))

N, LEN, K = 16384, 128, 10
data = randomwalk.generate(5, N, LEN)
q = jnp.asarray(queries.noisy_queries(data, 8))
truth = S.brute_force(q, jnp.asarray(data), K)

eng = DistributedEngine(mesh, axes=("data",), method="dstree")
print(f"building dstree over {eng.n_shards} shards ...")
eng.build(data, index=IndexSpec("dstree", leaf_cap=128))

for name, g in [("exact", Guarantee()),
                ("eps=1", Guarantee(epsilon=1.0)),
                ("ng(4)", Guarantee(nprobe=4))]:
    res = eng.query(q, K, g)
    m = workload_metrics(res.ids, res.dists, truth.ids, truth.dists)
    print(f"{name:8s} MAP={m['map']:.3f} recall={m['avg_recall']:.3f} "
          f"mre={m['mre']:.4f} "
          f"leaves(sum-shards)={int(res.leaves_visited[0])}")

res = eng.query(q, K, Guarantee())
m = workload_metrics(res.ids, res.dists, truth.ids, truth.dists)
assert m["map"] == 1.0, m
print("ok — sharded exact search matches the single-node brute force")
