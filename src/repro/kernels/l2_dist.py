"""Pallas TPU kernel: fused squared-Euclidean distance (refinement step).

The query-time hot loop of every method in the paper ("calcRealDist"):
candidate raw series stream through VMEM once; the kernel fuses the
-2*q@x^T MXU matmul with both norm terms so no separate norm passes touch
HBM. f32 accumulation regardless of input dtype; K is tiled so long
series (n = 256 .. 16384, the paper's settings) never exceed VMEM.

Grid: (B tiles, M tiles, K tiles); K is the innermost (sequential)
dimension and accumulates into the output block, which Pallas keeps
resident across K steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_kernel(q_ref, x_ref, out_ref, *, n_k: int):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)  # [TB, TK]
    x = x_ref[...].astype(jnp.float32)  # [TM, TK]
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [TB, TM]
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [TB, 1]
    xn = jnp.sum(x * x, axis=-1)  # [TM]
    out_ref[...] += qn - 2.0 * cross + xn[None, :]

    @pl.when(kstep == n_k - 1)
    def _clamp():
        out_ref[...] = jnp.maximum(out_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_m", "tile_k",
                                             "interpret"))
def l2_pallas(
    q: jax.Array,  # [B, n]
    x: jax.Array,  # [M, n]
    *,
    tile_b: int = 128,
    tile_m: int = 256,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, n = q.shape
    m = x.shape[0]
    tile_k = min(tile_k, n)
    assert b % tile_b == 0 and m % tile_m == 0 and n % tile_k == 0
    n_k = n // tile_k
    grid = (b // tile_b, m // tile_m, n_k)
    return pl.pallas_call(
        functools.partial(_l2_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(q, x)
