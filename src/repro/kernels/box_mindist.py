"""Pallas TPU kernel: weighted box lower-bound distance (filtering step).

The unified summary-space lower bound of iSAX (MINDIST to a SAX region),
DSTree (EAPCA [mean,std] region bound) and VA+file (cell bound): for query
summary q and box [lo, hi] with per-dim weights w,

    lb^2(q, box) = sum_d w_d * max(lo_d - q_d, q_d - hi_d, 0)^2 .

Grid is (query tiles, box tiles); each step broadcasts a [TB, D] query
tile against a [TL, D] box tile entirely in VMEM — for the paper's
settings (D = 16..32 summary dims) the [TB, TL, D] intermediate fits
comfortably (128*128*32*4B = 2 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _box_kernel(q_ref, lo_ref, hi_ref, w_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)     # [TB, D]
    lo = lo_ref[...].astype(jnp.float32)   # [TL, D]
    hi = hi_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)     # [1, D]
    d = jnp.maximum(
        jnp.maximum(lo[None, :, :] - q[:, None, :],
                    q[:, None, :] - hi[None, :, :]),
        0.0,
    )
    out_ref[...] = jnp.sum(d * d * w[None, :, :], axis=-1)


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_l",
                                             "interpret"))
def box_mindist_pallas(
    q: jax.Array,        # [B, D]
    lo: jax.Array,       # [L, D]
    hi: jax.Array,       # [L, D]
    weights: jax.Array,  # [D]
    *,
    tile_b: int = 128,
    tile_l: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, d = q.shape
    l = lo.shape[0]
    assert b % tile_b == 0 and l % tile_l == 0, (b, l, tile_b, tile_l)
    w2 = weights.reshape(1, d)
    grid = (b // tile_b, l // tile_l)
    return pl.pallas_call(
        _box_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_l, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_l, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_l), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, l), jnp.float32),
        interpret=interpret,
    )(q, lo, hi, w2)
