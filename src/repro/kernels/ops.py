"""Jit'd public wrappers around the Pallas kernels.

Each op pads inputs to tile boundaries, dispatches to the Pallas kernel on
TPU (or when forced via ``force_pallas=True``, which uses interpret mode on
CPU) and to the jnp oracle otherwise, then strips padding. The search core
calls these ops exclusively, so the TPU/CPU split lives in one place.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .box_mindist import box_mindist_pallas
from .l2_dist import l2_pallas
from .paa import paa_pallas
from .pq_adc import pq_adc_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, mult: int, value=0.0) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                   constant_values=value)


def paa(x: jax.Array, n_segments: int, *, force_pallas: bool = False,
        tile: int = 256) -> jax.Array:
    """Segment means [N, n] -> [N, l] f32."""
    if force_pallas or on_tpu():
        n = x.shape[0]
        xp = _pad_rows(x, tile)
        out = paa_pallas(xp, n_segments, tile=tile,
                         interpret=not on_tpu())
        return out[:n]
    return ref.ref_paa(x, n_segments)


def box_mindist(
    q: jax.Array, lo: jax.Array, hi: jax.Array, weights: jax.Array,
    *, force_pallas: bool = False, tile_b: int = 128, tile_l: int = 512,
) -> jax.Array:
    """Squared weighted box distances [B, L]."""
    if force_pallas or on_tpu():
        b, l = q.shape[0], lo.shape[0]
        qp = _pad_rows(q, tile_b)
        lop = _pad_rows(lo, tile_l)
        hip = _pad_rows(hi, tile_l)
        out = box_mindist_pallas(
            qp, lop, hip, weights, tile_b=tile_b, tile_l=tile_l,
            interpret=not on_tpu(),
        )
        return out[:b, :l]
    return ref.ref_box_mindist(q, lo, hi, weights)


def l2(
    q: jax.Array, x: jax.Array, *, force_pallas: bool = False,
    tile_b: int = 128, tile_m: int = 256, tile_k: int = 512,
) -> jax.Array:
    """Squared Euclidean distances [B, M] f32."""
    if force_pallas or on_tpu():
        b, m = q.shape[0], x.shape[0]
        n = q.shape[1]
        tile_k = min(tile_k, n)
        if n % tile_k:
            padk = (-n) % tile_k
            q = jnp.pad(q, ((0, 0), (0, padk)))
            x = jnp.pad(x, ((0, 0), (0, padk)))
        qp = _pad_rows(q, tile_b)
        xp = _pad_rows(x, tile_m)
        out = l2_pallas(qp, xp, tile_b=tile_b, tile_m=tile_m,
                        tile_k=tile_k, interpret=not on_tpu())
        return out[:b, :m]
    return ref.ref_l2(q, x)


def pq_adc(
    codes: jax.Array, lut: jax.Array, *, force_pallas: bool = False,
    tile_m: int = 512,
) -> jax.Array:
    """ADC scan distances [M]."""
    if force_pallas or on_tpu():
        m = codes.shape[0]
        cp = _pad_rows(codes, tile_m)
        out = pq_adc_pallas(cp, lut, tile_m=tile_m,
                            interpret=not on_tpu())
        return out[:m]
    return ref.ref_pq_adc(codes, lut)


def pq_adc_batch(
    codes: jax.Array, luts: jax.Array, *, force_pallas: bool = False,
) -> jax.Array:
    """Batched ADC scan: luts [B, m, K] per-query tables; codes [M, m]
    (one shared row set -> every query scores every row, the
    cooperative-gather regime) or [B, M, m] (per-lane rows). -> [B, M].

    TPU path reuses the pq_adc one-hot MXU trick: codes expand to a
    one-hot [*, m*K] tile contracted against the flattened LUTs — for
    shared codes that is ONE [B, m*K] x [m*K, M] matmul scoring every
    gathered row against all query lanes.
    """
    if force_pallas or on_tpu():
        b, m, k = luts.shape
        lf = luts.astype(jnp.float32)
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), k,
                                dtype=jnp.float32)
        if codes.ndim == 2:
            return jax.lax.dot_general(
                lf.reshape(b, m * k),
                onehot.reshape(-1, m * k),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        return jnp.einsum("bmjk,bjk->bm", onehot, lf,
                          preferred_element_type=jnp.float32)
    return ref.ref_pq_adc_batch(codes, luts)


def l2_topk(
    q: jax.Array, x: jax.Array, k: int, **kw
) -> Tuple[jax.Array, jax.Array]:
    """Fused distance + top-k: returns (dists [B,k] asc, ids [B,k])."""
    d = l2(q, x, **kw)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


def topk_merge(dists, ids, top_d, top_i):
    """Merge a candidate batch into running sorted top-k rows."""
    return ref.ref_topk_merge(dists, ids, top_d, top_i)


def topk_merge_unique(dists, ids, top_d, top_i):
    """topk_merge that keeps each id at most once (best distance).
    Required by the cooperative (share_gathers) scoring paths, where a
    leaf pooled at two iterations is scored twice for every lane."""
    return ref.ref_topk_merge_unique(dists, ids, top_d, top_i)
