"""Jit'd public wrappers around the Pallas kernels.

Each op pads inputs to tile boundaries, dispatches to the Pallas kernel on
TPU (or when forced via ``force_pallas=True``, which uses interpret mode on
CPU) and to the jnp oracle otherwise, then strips padding. The search core
calls these ops exclusively, so the TPU/CPU split lives in one place.

Every dispatch site is wrapped in ``jax.named_scope`` (the ``_scoped``
decorator): the op name lands on the emitted HLO/profiler metadata, so
device traces captured with jax.profiler attribute kernel time to
``repro.ops.<name>`` regions. named_scope is trace-time-only — zero
runtime cost, on or off.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _scoped(fn):
    """Wrap an op in jax.named_scope("repro.ops.<name>")."""

    @functools.wraps(fn)
    def wrapped(*a, **kw):
        with jax.named_scope(f"repro.ops.{fn.__name__}"):
            return fn(*a, **kw)

    return wrapped

from . import ref
from .box_mindist import box_mindist_pallas
from .l2_dist import l2_pallas
from .paa import paa_pallas
from .pq_adc import pq_adc_pallas
from .pq_adc_select import pq_adc_select_pallas
from .topk import coop_score_select_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, mult: int, value=0.0) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                   constant_values=value)


@_scoped
def paa(x: jax.Array, n_segments: int, *, force_pallas: bool = False,
        tile: int = 256) -> jax.Array:
    """Segment means [N, n] -> [N, l] f32."""
    if force_pallas or on_tpu():
        n = x.shape[0]
        xp = _pad_rows(x, tile)
        out = paa_pallas(xp, n_segments, tile=tile,
                         interpret=not on_tpu())
        return out[:n]
    return ref.ref_paa(x, n_segments)


@_scoped
def box_mindist(
    q: jax.Array, lo: jax.Array, hi: jax.Array, weights: jax.Array,
    *, force_pallas: bool = False, tile_b: int = 128, tile_l: int = 512,
) -> jax.Array:
    """Squared weighted box distances [B, L]."""
    if force_pallas or on_tpu():
        b, l = q.shape[0], lo.shape[0]
        qp = _pad_rows(q, tile_b)
        lop = _pad_rows(lo, tile_l)
        hip = _pad_rows(hi, tile_l)
        out = box_mindist_pallas(
            qp, lop, hip, weights, tile_b=tile_b, tile_l=tile_l,
            interpret=not on_tpu(),
        )
        return out[:b, :l]
    return ref.ref_box_mindist(q, lo, hi, weights)


@_scoped
def l2(
    q: jax.Array, x: jax.Array, *, force_pallas: bool = False,
    tile_b: int = 128, tile_m: int = 256, tile_k: int = 512,
) -> jax.Array:
    """Squared Euclidean distances [B, M] f32."""
    if force_pallas or on_tpu():
        b, m = q.shape[0], x.shape[0]
        n = q.shape[1]
        tile_k = min(tile_k, n)
        if n % tile_k:
            padk = (-n) % tile_k
            q = jnp.pad(q, ((0, 0), (0, padk)))
            x = jnp.pad(x, ((0, 0), (0, padk)))
        qp = _pad_rows(q, tile_b)
        xp = _pad_rows(x, tile_m)
        out = l2_pallas(qp, xp, tile_b=tile_b, tile_m=tile_m,
                        tile_k=tile_k, interpret=not on_tpu())
        return out[:b, :m]
    return ref.ref_l2(q, x)


@_scoped
def pq_adc(
    codes: jax.Array, lut: jax.Array, *, force_pallas: bool = False,
    tile_m: int = 512,
) -> jax.Array:
    """ADC scan distances [M]."""
    if force_pallas or on_tpu():
        m = codes.shape[0]
        cp = _pad_rows(codes, tile_m)
        out = pq_adc_pallas(cp, lut, tile_m=tile_m,
                            interpret=not on_tpu())
        return out[:m]
    return ref.ref_pq_adc(codes, lut)


@_scoped
def pq_adc_batch(
    codes: jax.Array, luts: jax.Array, *, force_pallas: bool = False,
) -> jax.Array:
    """Batched ADC scan: luts [B, m, K] per-query tables; codes [M, m]
    (one shared row set -> every query scores every row, the
    cooperative-gather regime) or [B, M, m] (per-lane rows). -> [B, M].

    TPU path reuses the pq_adc one-hot MXU trick: codes expand to a
    one-hot [*, m*K] tile contracted against the flattened LUTs — for
    shared codes that is ONE [B, m*K] x [m*K, M] matmul scoring every
    gathered row against all query lanes.
    """
    if force_pallas or on_tpu():
        b, m, k = luts.shape
        lf = luts.astype(jnp.float32)
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), k,
                                dtype=jnp.float32)
        if codes.ndim == 2:
            return jax.lax.dot_general(
                lf.reshape(b, m * k),
                onehot.reshape(-1, m * k),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        return jnp.einsum("bmjk,bjk->bm", onehot, lf,
                          preferred_element_type=jnp.float32)
    return ref.ref_pq_adc_batch(codes, luts)


@_scoped
def l2_topk(
    q: jax.Array, x: jax.Array, k: int, **kw
) -> Tuple[jax.Array, jax.Array]:
    """Fused distance + top-k: returns (dists [B,k] asc, ids [B,k])."""
    d = l2(q, x, **kw)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


@_scoped
def row_sq_norms(rows: jax.Array) -> jax.Array:
    """Per-row squared L2 norms [N, n] -> [N] f32.

    THE norm computation of the serving path: FrozenIndex freeze,
    save_index sidecar, LeafStore open and every fallback all call this
    one function so cached-vs-recomputed norms stay bit-identical.
    """
    rf = rows.astype(jnp.float32)
    return jnp.sum(rf * rf, axis=-1)


@_scoped
def sq_l2(q: jax.Array, rows: jax.Array, row_norms: jax.Array
          ) -> jax.Array:
    """Fused squared-L2 with precomputed row norms (f32 accumulation).

    q [B, n]; rows [R, n] -> [B, R] pooled (one MXU matmul scoring
    every row against every lane — the cooperative regime) or rows
    [B, M, n] -> [B, M] per-lane (row_norms [B, M]). The single
    ``astype(f32)`` + norms-passed-in replaces the three copy-pasted
    variants that previously lived in core/search.py and store/ooc.py.
    """
    qf = q.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1)[:, None]
    rf = rows.astype(jnp.float32)
    rn = row_norms.astype(jnp.float32)
    if rows.ndim == 2:
        return jnp.maximum(qn - 2.0 * (qf @ rf.T) + rn[None, :], 0.0)
    cross = jnp.einsum("bn,bmn->bm", qf, rf,
                       preferred_element_type=jnp.float32)
    return jnp.maximum(qn - 2.0 * cross + rn, 0.0)


def _select_k_by_d(dists, ids, kk: int):
    """Per-row kk smallest candidates by distance, ties by column.

    lax.top_k prefers the lower index on ties, which is exactly the
    order a stable full sort gives candidates — so the selection drops
    only elements that could never reach the merged top-k.
    Output is sorted ascending (ties column-ascending).
    """
    neg_d, pos = jax.lax.top_k(-dists, kk)
    return -neg_d, jnp.take_along_axis(ids, pos, axis=1)


def _select_k_by_d_id_shared(dists, ids, kk: int):
    """Per-row kk lexicographically-smallest (d, id) pairs when the
    candidate ids are LANE-INVARIANT (ids [R], dists [B, R]) — every
    cooperative call site, since the pooled rows are shared.

    One cheap 1-D argsort of the R ids permutes the candidate COLUMNS
    into id order; a single f32 top_k then breaks distance ties by
    permuted position = by id, which IS the (d, id)-lex selection,
    int32-exact, already in canonical order. One TopK total: XLA:CPU
    rewrites a lone top_k to its fast custom call, but a top_k whose
    operand depends on another top_k is left as a full O(R log R) sort
    (measured ~70x slower at cooperative width), so threshold-style
    two-pass selection is a trap here.
    """
    order = jnp.argsort(ids.astype(jnp.int32))
    d_p = dists[:, order]
    ids_p = ids.astype(jnp.int32)[order]
    neg, pos = jax.lax.top_k(-d_p, kk)
    return -neg, jnp.take(ids_p, pos)


def _select_k_by_d_id(dists, ids, kk: int):
    """Per-row kk lexicographically-smallest (d, id) pairs, sorted —
    the generic [B, M] per-row-ids form (property tests; real callers
    with shared pools use _select_k_by_d_id_shared).

    Two top_k passes: pass 1 finds the kk-th smallest distance (the
    selection threshold); pass 2 re-ranks only the threshold TIES by
    id, so the selected SET matches the full (d, id) sort; a width-kk
    2-key sort canonicalizes the order. Pass-2 keys are f32 (ids exact
    below 2^24; above, float rounding only weakens WHICH of several
    equal-distance candidates crosses the selection boundary — a
    deterministic, guarantee-preserving tie-break, distances
    identical; the final int32 2-key sort keeps the emitted order
    exact regardless).
    """
    ids = ids.astype(jnp.int32)
    neg_d, _ = jax.lax.top_k(-dists, kk)
    thr = -neg_d[:, -1:]  # [B, 1] kk-th smallest distance
    key = jnp.where(
        dists < thr, jnp.float32(jnp.inf),
        jnp.where(dists == thr, -ids.astype(jnp.float32),
                  jnp.float32(-jnp.inf)))
    # repro: allow[jax-topk-on-topk] deliberate trade-off documented above: this is the generic per-row-ids fallback (property tests); real call sites use the single-TopK _select_k_by_d_id_shared
    _, pos = jax.lax.top_k(key, kk)
    sel_d = jnp.take_along_axis(dists, pos, axis=1)
    sel_i = jnp.take_along_axis(ids, pos, axis=1)
    return jax.lax.sort((sel_d, sel_i), num_keys=2)


@_scoped
def bitonic_merge_sorted(da, ia, db, ib):
    """Merge two per-row sorted (ascending) lists: [B,ka]+[B,kb] ->
    [B,ka+kb], the k+k bitonic-merge stage of :func:`topk_merge`.

    Each element is tagged with its concatenation position; compares
    are (d, tag)-lexicographic, so keys are unique and the
    compare-exchange network reproduces the STABLE merge exactly
    (a-list wins distance ties, as in the full-sort oracle). log2(W)
    stages of [B, W] where-swaps, W = ka+kb padded to a power of two.
    """
    b, ka = da.shape
    kb = db.shape[1]
    total = ka + kb
    w = 1 if total == 1 else 1 << (total - 1).bit_length()
    pad = w - total
    tag_a = jnp.broadcast_to(jnp.arange(ka, dtype=jnp.int32), (b, ka))
    tag_b = jnp.broadcast_to(
        jnp.arange(ka, w, dtype=jnp.int32), (b, kb + pad))
    db_p = jnp.pad(db, ((0, 0), (0, pad)), constant_values=jnp.inf)
    ib_p = jnp.pad(ib, ((0, 0), (0, pad)), constant_values=-1)
    # A asc ++ reverse(B asc) = one bitonic sequence in (d, tag)
    d = jnp.concatenate([da, jnp.flip(db_p, axis=1)], axis=1)
    i = jnp.concatenate([ia, jnp.flip(ib_p, axis=1)], axis=1)
    t = jnp.concatenate([tag_a, jnp.flip(tag_b, axis=1)], axis=1)
    step = w // 2
    while step >= 1:
        sh = (b, w // (2 * step), 2, step)
        dr, ir, tr = d.reshape(sh), i.reshape(sh), t.reshape(sh)
        d0, d1 = dr[:, :, 0], dr[:, :, 1]
        i0, i1 = ir[:, :, 0], ir[:, :, 1]
        t0, t1 = tr[:, :, 0], tr[:, :, 1]
        swap = (d1 < d0) | ((d1 == d0) & (t1 < t0))
        d = jnp.stack([jnp.where(swap, d1, d0),
                       jnp.where(swap, d0, d1)], axis=2).reshape(b, w)
        i = jnp.stack([jnp.where(swap, i1, i0),
                       jnp.where(swap, i0, i1)], axis=2).reshape(b, w)
        t = jnp.stack([jnp.where(swap, t1, t0),
                       jnp.where(swap, t0, t1)], axis=2).reshape(b, w)
        step //= 2
    return d[:, :total], i[:, :total]


@_scoped
def topk_merge(dists, ids, top_d, top_i):
    """Merge a candidate batch into running sorted top-k rows.

    Selection formulation (bit-exact to :func:`ref.ref_topk_merge`,
    ties included): lax.top_k picks the k best candidates — O(M log k)
    instead of sorting the full k+M width — then a k+k bitonic merge
    of the two sorted lists keeps per-iteration merge cost O(k log k)
    independent of candidate width (docs/PERF.md)."""
    k = top_d.shape[1]
    kk = min(k, dists.shape[1])
    sel_d, sel_i = _select_k_by_d(dists, ids, kk)
    md, mi = bitonic_merge_sorted(top_d, top_i, sel_d, sel_i)
    return md[:, :k], mi[:, :k]


@_scoped
def dedup_merge_topk(sel_d, sel_i, top_d, top_i):
    """Fold PRE-SELECTED candidates [B, kk] into the running top-k with
    id dedup — the merge half of :func:`topk_merge_unique`, shared with
    the fused cooperative kernel path. Id-dedup runs over the k+kk
    survivors only (two tiny sorts), never the full candidate width;
    the op sequence matches the full-sort oracle so placeholders and
    (d, id) tie order come out identical."""
    k = top_d.shape[1]
    all_d = jnp.concatenate([top_d, sel_d], axis=1)
    all_i = jnp.concatenate([top_i, sel_i.astype(top_i.dtype)], axis=1)
    si, sd = jax.lax.sort((all_i, all_d), num_keys=2)
    dup = jnp.concatenate(
        [jnp.zeros_like(si[:, :1], bool), si[:, 1:] == si[:, :-1]],
        axis=1)
    sd = jnp.where(dup, jnp.float32(jnp.inf), sd)
    si = jnp.where(dup, -1, si)
    new_d, new_i = jax.lax.sort((sd, si), num_keys=1)
    return new_d[:, :k], new_i[:, :k]


@_scoped
def topk_merge_unique(dists, ids, top_d, top_i):
    """topk_merge that keeps each id at most once (best distance).
    Required by the cooperative (share_gathers) scoring paths, where a
    leaf pooled at two iterations is scored twice for every lane.

    Selection formulation (bit-exact to ref.ref_topk_merge_unique):
    select 2k candidates by (d, id) — k fresh winners can hide behind
    at most k duplicates of running entries — then dedup among the
    <=3k survivors only. ``ids`` may be [M] (lane-invariant pool, the
    cooperative call sites: fast single-TopK path) or [B, M] (per-lane
    ids — the engine's cross-shard fold, where each shard's sorted
    top-k merges into the global answer and shard ids are globally
    disjoint). PRECONDITION (call-site invariant, enforced by the
    per-iteration leaf dedup in the shared refinement core
    core/refine.py, and by disjoint shard ranges in the engine fold):
    each real id appears at most once among the candidate columns;
    only the -1 placeholder repeats. Candidate ids duplicating RUNNING
    entries are fine at any distance."""
    k = top_d.shape[1]
    kk = min(2 * k, dists.shape[1])
    if ids.ndim == 1:
        sel_d, sel_i = _select_k_by_d_id_shared(dists, ids, kk)
    else:
        sel_d, sel_i = _select_k_by_d_id(dists, ids, kk)
    return dedup_merge_topk(sel_d, sel_i, top_d, top_i)


@_scoped
def pq_adc_select(
    codes: jax.Array,  # [R, m] pooled code rows (shared across lanes)
    luts: jax.Array,   # [B, m, K] f32 per-lane ADC tables
    ids: jax.Array,    # [R] int32, -1 = masked slot
    kk: int,
    *,
    force_pallas: bool = False,
    tile_b: int = 128,
    tile_r: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Fused cooperative PQ-ADC score+select: per lane, the kk best
    (d, id) candidates from the pooled code rows, without
    materializing the [B, R] ADC distance matrix in HBM on TPU
    (kernels/pq_adc_select.py streams the uint8 codes through the
    one-hot MXU contraction tile by tile and keeps the running
    selection in VMEM). CPU path is the jnp oracle formulation
    (ref_pq_adc_batch + the shared-pool partial selection) — bit-exact
    to the pre-fusion pq_adc_batch + topk_merge_unique corner. Output
    feeds dedup_merge_topk."""
    # kk > R would diverge across backends (the padded Pallas path
    # emits placeholder columns, the oracle's top_k raises) — callers
    # clamp (min(2k, R)); make the contract explicit at trace time
    assert kk <= codes.shape[0], (kk, codes.shape)
    if force_pallas or on_tpu():
        b = luts.shape[0]
        lp = _pad_rows(luts, tile_b)
        cp = _pad_rows(codes.astype(jnp.int32), tile_r)
        ip = _pad_rows(ids.astype(jnp.int32)[:, None], tile_r, value=-1)
        od, oi = pq_adc_select_pallas(
            cp, lp, ip, kk, tile_b=tile_b, tile_r=tile_r,
            interpret=not on_tpu())
        return od[:b], oi[:b]
    d = ref.ref_pq_adc_batch(codes, luts)
    d = jnp.where(ids[None, :] < 0, jnp.float32(jnp.inf), d)
    return _select_k_by_d_id_shared(d, ids, kk)


@_scoped
def coop_score_select(
    q: jax.Array,          # [B, n] f32 queries
    rows: jax.Array,       # [R, n] pooled rows (index/payload dtype)
    row_norms: jax.Array,  # [R] f32 cached squared norms
    ids: jax.Array,        # [R] int32, -1 = masked slot
    kk: int,
    *,
    force_pallas: bool = False,
    tile_b: int = 128,
    tile_r: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Fused cooperative score+select: per lane, the kk best (d, id)
    candidates from the pooled rows, without materializing the [B, R]
    distance matrix in HBM on TPU (kernels/topk.py tiles R and keeps
    the running selection in VMEM). CPU path is the jnp oracle
    (sq_l2 + partial selection). Output feeds dedup_merge_topk."""
    # same kk <= R contract as pq_adc_select (backend divergence
    # otherwise); all call sites clamp kk = min(2k, R)
    assert kk <= rows.shape[0], (kk, rows.shape)
    if force_pallas or on_tpu():
        b = q.shape[0]
        qp = _pad_rows(q, tile_b)
        rp = _pad_rows(rows, tile_r)
        rn_p = _pad_rows(row_norms[:, None], tile_r)
        ip = _pad_rows(ids.astype(jnp.int32)[:, None], tile_r, value=-1)
        od, oi = coop_score_select_pallas(
            qp, rp, rn_p, ip, kk, tile_b=tile_b, tile_r=tile_r,
            interpret=not on_tpu())
        return od[:b], oi[:b]
    d = sq_l2(q.astype(jnp.float32), rows, row_norms)
    d = jnp.where(ids[None, :] < 0, jnp.float32(jnp.inf), d)
    return _select_k_by_d_id_shared(d, ids, kk)
