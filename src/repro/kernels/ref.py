"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``ref_*`` function is the semantic definition; kernels must match it
to float tolerance across the shape/dtype sweeps in tests/test_kernels.py.
These are also the CPU execution path (ops.py dispatches here when not on
TPU), so they are written to be reasonably efficient jnp, not golden-file
stubs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_paa(x: jax.Array, n_segments: int) -> jax.Array:
    """Piecewise Aggregate Approximation. x [N, n] -> [N, l] segment means.

    Requires n % l == 0 (paper setting: n=256, l=16).
    """
    n = x.shape[-1]
    assert n % n_segments == 0, (n, n_segments)
    w = n // n_segments
    return x.reshape(x.shape[:-1] + (n_segments, w)).mean(
        axis=-1, dtype=jnp.float32
    )


def ref_box_mindist(
    q: jax.Array,      # [B, D] query summary coordinates
    lo: jax.Array,     # [L, D] box lower bounds
    hi: jax.Array,     # [L, D] box upper bounds
    weights: jax.Array,  # [D] per-dim weight (segment lengths etc.)
) -> jax.Array:
    """Weighted squared box distance: the unified lower bound of iSAX
    (MINDIST), DSTree (EAPCA region bound) and VA+file (cell bound).

    Returns SQUARED lb distances [B, L]; callers sqrt at the end.
    """
    qf = q.astype(jnp.float32)[:, None, :]
    lof = lo.astype(jnp.float32)[None]
    hif = hi.astype(jnp.float32)[None]
    d = jnp.maximum(jnp.maximum(lof - qf, qf - hif), 0.0)
    return jnp.sum(d * d * weights.astype(jnp.float32)[None, None, :],
                   axis=-1)


def ref_l2(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared Euclidean distances. q [B, n], x [M, n] -> [B, M] f32.

    Matmul-form (MXU-friendly): |q|^2 - 2 q.x + |x|^2, f32 accumulation.
    """
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)  # [B,1]
    xn = jnp.sum(xf * xf, axis=-1)  # [M]
    cross = qf @ xf.T
    return jnp.maximum(qn - 2.0 * cross + xn[None, :], 0.0)


def ref_pq_adc(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """PQ asymmetric distance scan.

    codes [M, m] int32 in [0, K); lut [m, K] f32 per-subspace distance
    table for one query. Returns [M] summed distances.
    """
    m = codes.shape[1]
    # per-subspace gather: lut[j, codes[:, j]] summed over j
    idx = codes.astype(jnp.int32)
    out = jnp.zeros(codes.shape[0], jnp.float32)
    for j in range(m):
        out = out + jnp.take(lut[j], idx[:, j])
    return out


def ref_pq_adc_batch(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Batched PQ asymmetric distance scan.

    luts [B, m, K] per-query subspace tables; codes either [M, m]
    (one shared row set scored against every query — the cooperative
    gather regime) or [B, M, m] (per-lane rows). Returns [B, M].
    Gather formulation — the CPU oracle for the one-hot MXU path.
    """
    b, m, _ = luts.shape
    idx = codes.astype(jnp.int32)
    if idx.ndim == 2:
        idx = jnp.broadcast_to(idx[None], (b,) + idx.shape)
    g = jnp.take_along_axis(
        jnp.broadcast_to(luts[:, None], (b, idx.shape[1], m,
                                         luts.shape[2])),
        idx[..., None], axis=3)
    return g[..., 0].sum(-1)


def ref_pq_adc_select(
    codes: jax.Array,  # [R, m] pooled code rows (shared across lanes)
    luts: jax.Array,   # [B, m, K] per-lane ADC tables
    ids: jax.Array,    # [R] int32 candidate ids, -1 = masked slot
    kk: int,
) -> tuple:
    """Oracle for the fused PQ-ADC score+select kernel — the
    FULL-MATERIALIZATION formulation: ADC-score every pooled code row
    against every lane's table into a [B, R] matrix (masked slots at
    +inf), then return per lane the ``kk`` lexicographically-smallest
    (d, id) pairs sorted by (d, id) — the candidate half of
    ``ops.topk_merge_unique``'s selection stage, exactly what the
    pre-fusion cooperative pq path computed. Precondition (call-site
    invariant): real ids are distinct within the pool; only the -1
    placeholder repeats.
    """
    d = ref_pq_adc_batch(codes, luts)                      # [B, R]
    d = jnp.where(ids[None, :] < 0, jnp.float32(jnp.inf), d)
    b = luts.shape[0]
    idm = jnp.broadcast_to(ids.astype(jnp.int32)[None, :],
                           (b, ids.shape[0]))
    sd, si = jax.lax.sort((d, idm), num_keys=2)
    return sd[:, :kk], si[:, :kk]


def ref_topk_merge(
    dists: jax.Array,  # [B, M] candidate distances
    ids: jax.Array,    # [B, M] candidate ids
    top_d: jax.Array,  # [B, k] current best distances (sorted asc)
    top_i: jax.Array,  # [B, k] current best ids
) -> tuple:
    """Merge candidates into running sorted top-k rows.

    Full-sort formulation: O((k+M) log (k+M)) comparator depth over the
    whole candidate width. Kept as the semantic oracle AND the timing
    baseline for the selection-based ``ops.topk_merge`` (docs/PERF.md),
    which must agree bit-exactly, ties included: this sort is stable, so
    distance ties resolve by concatenation position (running entries
    first, then candidates in block order).
    """
    k = top_d.shape[1]
    all_d = jnp.concatenate([top_d, dists], axis=1)
    all_i = jnp.concatenate([top_i, ids], axis=1)
    new_d, new_i = jax.lax.sort((all_d, all_i), num_keys=1)
    return new_d[:, :k], new_i[:, :k]


def ref_topk_merge_unique(
    dists: jax.Array,  # [B, M] candidate distances
    ids: jax.Array,    # [B, M] candidate ids
    top_d: jax.Array,  # [B, k] current best (asc, ids distinct)
    top_i: jax.Array,  # [B, k]
) -> tuple:
    """topk_merge with id dedup: each id keeps only its best distance.

    The cooperative (share_gathers) path needs this: a leaf pooled at
    two different iterations (two lanes visiting it at different ranks)
    is scored TWICE for every lane, and without dedup the duplicates
    (a) collapse the returned top-k to fewer distinct neighbors and
    (b) shrink the kth-best below the true kth-distinct distance,
    making the stopping predicate prune too early — an exactness bug,
    not just cosmetics. Sort by (id, d) to cluster duplicates (best
    first), mask all but the first of each run, re-sort by distance.
    Masked/invalid candidates (id -1, d inf) collapse to one placeholder
    which sorts last, so they never displace real neighbors.

    Full-sort formulation: TWO sorts over the whole k+M cooperative
    width. Kept as the semantic oracle and timing baseline for the
    selection-based ``ops.topk_merge_unique`` (docs/PERF.md). Note the
    resulting order is (d, id)-lexicographic: the second sort is stable
    over the id-sorted sequence, so distance ties come out id-ascending.
    """
    k = top_d.shape[1]
    all_d = jnp.concatenate([top_d, dists], axis=1)
    all_i = jnp.concatenate([top_i, ids], axis=1)
    si, sd = jax.lax.sort((all_i, all_d), num_keys=2)
    dup = jnp.concatenate(
        [jnp.zeros_like(si[:, :1], bool), si[:, 1:] == si[:, :-1]],
        axis=1)
    sd = jnp.where(dup, jnp.float32(jnp.inf), sd)
    si = jnp.where(dup, -1, si)
    new_d, new_i = jax.lax.sort((sd, si), num_keys=1)
    return new_d[:, :k], new_i[:, :k]


def ref_coop_score_select(
    q: jax.Array,          # [B, n] f32 queries
    rows: jax.Array,       # [R, n] pooled candidate rows (any dtype)
    row_norms: jax.Array,  # [R] f32 precomputed squared row norms
    ids: jax.Array,        # [R] int32 candidate ids, -1 = masked slot
    kk: int,
) -> tuple:
    """Oracle for the fused cooperative score+select kernel.

    Scores every pooled row against every query lane (|q|^2 - 2 q.x +
    |x|^2 with the norms passed in, masked slots at +inf) and returns,
    per lane, the ``kk`` lexicographically-smallest (d, id) pairs sorted
    by (d, id) — the candidate half of ``ops.topk_merge_unique``'s
    selection stage. Precondition (call-site invariant): real ids are
    distinct within the pool; only the -1 placeholder repeats.
    """
    qf = q.astype(jnp.float32)
    rf = rows.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1)[:, None]
    d = jnp.maximum(qn - 2.0 * (qf @ rf.T)
                    + row_norms.astype(jnp.float32)[None, :], 0.0)
    d = jnp.where(ids[None, :] < 0, jnp.float32(jnp.inf), d)
    b = q.shape[0]
    idm = jnp.broadcast_to(ids.astype(jnp.int32)[None, :],
                           (b, ids.shape[0]))
    sd, si = jax.lax.sort((d, idm), num_keys=2)
    return sd[:, :kk], si[:, :kk]
