"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``ref_*`` function is the semantic definition; kernels must match it
to float tolerance across the shape/dtype sweeps in tests/test_kernels.py.
These are also the CPU execution path (ops.py dispatches here when not on
TPU), so they are written to be reasonably efficient jnp, not golden-file
stubs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_paa(x: jax.Array, n_segments: int) -> jax.Array:
    """Piecewise Aggregate Approximation. x [N, n] -> [N, l] segment means.

    Requires n % l == 0 (paper setting: n=256, l=16).
    """
    n = x.shape[-1]
    assert n % n_segments == 0, (n, n_segments)
    w = n // n_segments
    return x.reshape(x.shape[:-1] + (n_segments, w)).mean(
        axis=-1, dtype=jnp.float32
    )


def ref_box_mindist(
    q: jax.Array,      # [B, D] query summary coordinates
    lo: jax.Array,     # [L, D] box lower bounds
    hi: jax.Array,     # [L, D] box upper bounds
    weights: jax.Array,  # [D] per-dim weight (segment lengths etc.)
) -> jax.Array:
    """Weighted squared box distance: the unified lower bound of iSAX
    (MINDIST), DSTree (EAPCA region bound) and VA+file (cell bound).

    Returns SQUARED lb distances [B, L]; callers sqrt at the end.
    """
    qf = q.astype(jnp.float32)[:, None, :]
    lof = lo.astype(jnp.float32)[None]
    hif = hi.astype(jnp.float32)[None]
    d = jnp.maximum(jnp.maximum(lof - qf, qf - hif), 0.0)
    return jnp.sum(d * d * weights.astype(jnp.float32)[None, None, :],
                   axis=-1)


def ref_l2(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared Euclidean distances. q [B, n], x [M, n] -> [B, M] f32.

    Matmul-form (MXU-friendly): |q|^2 - 2 q.x + |x|^2, f32 accumulation.
    """
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1, keepdims=True)  # [B,1]
    xn = jnp.sum(xf * xf, axis=-1)  # [M]
    cross = qf @ xf.T
    return jnp.maximum(qn - 2.0 * cross + xn[None, :], 0.0)


def ref_pq_adc(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """PQ asymmetric distance scan.

    codes [M, m] int32 in [0, K); lut [m, K] f32 per-subspace distance
    table for one query. Returns [M] summed distances.
    """
    m = codes.shape[1]
    # per-subspace gather: lut[j, codes[:, j]] summed over j
    idx = codes.astype(jnp.int32)
    out = jnp.zeros(codes.shape[0], jnp.float32)
    for j in range(m):
        out = out + jnp.take(lut[j], idx[:, j])
    return out


def ref_topk_merge(
    dists: jax.Array,  # [B, M] candidate distances
    ids: jax.Array,    # [B, M] candidate ids
    top_d: jax.Array,  # [B, k] current best distances (sorted asc)
    top_i: jax.Array,  # [B, k] current best ids
) -> tuple:
    """Merge candidates into running sorted top-k rows."""
    k = top_d.shape[1]
    all_d = jnp.concatenate([top_d, dists], axis=1)
    all_i = jnp.concatenate([top_i, ids], axis=1)
    new_d, new_i = jax.lax.sort((all_d, all_i), num_keys=1)
    return new_d[:, :k], new_i[:, :k]
