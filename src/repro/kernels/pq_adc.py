"""Pallas TPU kernel: PQ asymmetric-distance (ADC) scan — IMI's hot loop.

For one query, per-subspace distance LUT lut [m, K] and PQ codes
codes [M, m], the scan computes dist[i] = sum_j lut[j, codes[i, j]].

TPU adaptation (docs/PERF.md §6): random per-lane gathers are the natural
CUDA formulation but map poorly onto the VPU; instead the code tile is
expanded to a one-hot matrix and contracted against the flattened LUT on
the MXU: onehot[TM, m*K] @ lut.flat[m*K] — a matmul-shaped scan that
streams codes through VMEM once. K=256, m<=32 keeps the one-hot tile
within VMEM (128 * 8192 * 4B = 4 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, lut_ref, out_ref, *, n_k: int):
    codes = codes_ref[...]  # [TM, m] int32
    lut = lut_ref[...].astype(jnp.float32)  # [m, K]
    tm, m = codes.shape
    k = lut.shape[1]
    sym = jax.lax.broadcasted_iota(jnp.int32, (tm, m, k), 2)
    onehot = (codes[:, :, None] == sym).astype(jnp.float32)
    flat = onehot.reshape(tm, m * k)
    out_ref[...] = jax.lax.dot_general(
        flat, lut.reshape(m * k, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def pq_adc_pallas(
    codes: jax.Array,  # [M, m] int32
    lut: jax.Array,    # [m, K] f32
    *,
    tile_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
    mm, m = codes.shape
    k = lut.shape[1]
    assert mm % tile_m == 0, (mm, tile_m)
    grid = (mm // tile_m,)
    out = pl.pallas_call(
        functools.partial(_adc_kernel, n_k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mm, 1), jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32), lut)
    return out[:, 0]
