"""Pallas TPU kernel: PAA summarization (segment means).

The build-time hot loop of iSAX/DSTree indexing: every series in the
collection is reduced to l segment means. One grid step processes a tile
of TN series resident in VMEM; the reduction reshapes the lane dimension
into (l, w) and means over w, which lowers to VPU reductions with the
sublane-major layout intact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paa_kernel(x_ref, out_ref, *, n_segments: int):
    x = x_ref[...].astype(jnp.float32)  # [TN, n]
    tn, n = x.shape
    w = n // n_segments
    seg = x.reshape(tn, n_segments, w)
    out_ref[...] = jnp.mean(seg, axis=-1)


@functools.partial(jax.jit, static_argnames=("n_segments", "tile",
                                             "interpret"))
def paa_pallas(
    x: jax.Array, n_segments: int, *, tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x [N, n] -> [N, l] f32 segment means. N must divide by `tile`
    (ops.py pads)."""
    n_rows, n = x.shape
    assert n % n_segments == 0
    assert n_rows % tile == 0, (n_rows, tile)
    grid = (n_rows // tile,)
    return pl.pallas_call(
        functools.partial(_paa_kernel, n_segments=n_segments),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, n_segments), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n_segments), jnp.float32),
        interpret=interpret,
    )(x)
