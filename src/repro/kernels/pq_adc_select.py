"""Pallas TPU kernel: fused PQ-ADC scoring + top-k select.

The cooperative pq refinement step scores every pooled uint8 code row
against every query lane's ADC table. Done as two ops (the
`ops.pq_adc_batch` one-hot matmul, then the merge) that materializes a
[B, R] = [B, B*V*M] f32 distance matrix in HBM each iteration — the
exact memory-bandwidth cost the PQ codec exists to avoid; the raw
(f32/bf16) cooperative path stopped paying it in PR 3
(kernels/topk.py). This kernel closes the pq corner: the pool
dimension R is tiled, each code tile is expanded to a one-hot matrix
and contracted against the flattened per-lane LUTs on the MXU
(`luts.flat[TB, m*K] @ onehot[TR, m*K].T` — the kernels/pq_adc.py
trick, batched over lanes), the [TB, TR] ADC distance tile lives only
in VMEM, and a running per-lane selection of the kk lexicographically
smallest (d, id) pairs is carried in the output block across R steps.
uint8 codes stream through VMEM once; per-iteration pq memory drops
from O(B^2*V*M) to O(B*k) (memory math in docs/PERF.md §4).

Selection is the shared ``kernels.topk.lex_min_select`` (kk rounds of
lex min-extraction, VPU reductions + where-masks only). Precondition
(as for ops.topk_merge_unique): real ids are distinct within the
pool; only the -1 placeholder repeats, and placeholder slots emit
exactly the (inf, -1) pairs the jnp oracle (ref.ref_pq_adc_select)
emits. VMEM budget at the default tiles (TB=128, TR=256, m=16,
K=256): one-hot tile 256x4096 f32 = 4 MiB + LUT tile 128x4096 f32 =
2 MiB, within the ~16 MB/core budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .topk import lex_min_select


def _pq_select_kernel(luts_ref, codes_ref, ids_ref, outd_ref,
                      outi_ref, *, kk: int, n_k: int):
    rstep = pl.program_id(1)

    @pl.when(rstep == 0)
    def _init():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    luts = luts_ref[...].astype(jnp.float32)  # [TB, m*K]
    codes = codes_ref[...]                    # [TR, m] int32
    ids = ids_ref[...]                        # [TR, 1] int32
    tr, m = codes.shape

    # one-hot MXU ADC: d[b, i] = sum_j luts[b, j, codes[i, j]]
    sym = jax.lax.broadcasted_iota(jnp.int32, (tr, m, n_k), 2)
    onehot = (codes[:, :, None] == sym).astype(jnp.float32)
    d = jax.lax.dot_general(
        luts, onehot.reshape(tr, m * n_k), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # [TB, TR]
    idv = ids[:, 0][None, :]                              # [1, TR]
    d = jnp.where(idv < 0, jnp.inf, d)
    idm = jnp.broadcast_to(idv, d.shape)

    # running selection ++ tile, then kk lex-min extractions
    cur_d = jnp.concatenate([outd_ref[...], d], axis=1)
    cur_i = jnp.concatenate([outi_ref[...], idm], axis=1)
    outd_ref[...], outi_ref[...] = lex_min_select(cur_d, cur_i, kk)


@functools.partial(jax.jit,
                   static_argnames=("kk", "tile_b", "tile_r",
                                    "interpret"))
def pq_adc_select_pallas(
    codes: jax.Array,  # [R, m] int32 pooled code rows
    luts: jax.Array,   # [B, m, K] f32 per-lane ADC tables
    ids: jax.Array,    # [R, 1] int32 candidate ids, -1 = masked
    kk: int,
    *,
    tile_b: int = 128,
    tile_r: int = 256,
    interpret: bool = False,
) -> tuple:
    b, m, k = luts.shape
    r = codes.shape[0]
    assert b % tile_b == 0 and r % tile_r == 0, (b, r, tile_b, tile_r)
    grid = (b // tile_b, r // tile_r)  # R innermost: sequential carry
    return pl.pallas_call(
        functools.partial(_pq_select_kernel, kk=kk, n_k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, m * k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_r, m), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, kk), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kk), jnp.float32),
            jax.ShapeDtypeStruct((b, kk), jnp.int32),
        ],
        interpret=interpret,
    )(luts.astype(jnp.float32).reshape(b, m * k),
      codes.astype(jnp.int32), ids.astype(jnp.int32))
