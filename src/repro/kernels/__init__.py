# Pallas TPU kernels for the paper's compute hot-spots:
#   paa.py         — PAA summarization (index build)
#   box_mindist.py — unified summary lower bound (filter step)
#   l2_dist.py     — fused raw-distance refinement ("calcRealDist")
#   pq_adc.py      — IMI PQ asymmetric-distance scan
#   topk.py        — fused cooperative score + top-k select (share path)
# ops.py = jit'd wrappers with CPU fallback; ref.py = pure-jnp oracles.
from . import ops, ref

__all__ = ["ops", "ref"]
