"""Pallas TPU kernel: fused cooperative score + top-k select.

The cooperative (share_gathers) refinement step scores every pooled
candidate row against every query lane. Done naively that materializes
a [B, R] = [B, B*V*M] distance matrix in HBM each iteration, only for
the merge to keep k << R entries per lane. This kernel fuses the two:
the pool dimension R is tiled, each [TB, TR] distance tile lives only
in VMEM, and a running per-lane selection of the kk lexicographically
smallest (d, id) pairs is carried in the output block across R steps —
TPU never writes the distance matrix out (DESIGN ref: docs/PERF.md).

Selection inside the kernel is kk rounds of lexicographic min-extraction
over the [TB, kk + TR] concat of the running selection and the tile
(VPU reductions + where-masks only — no sort network, no gathers), which
keeps every op Pallas-TPU friendly. Extracted slots are remasked to the
(inf, -1) placeholder, so exhausted tiles emit exactly the placeholder
the jnp oracle (ref.ref_coop_score_select) emits. Precondition (as for
ops.topk_merge_unique): real ids are distinct within the pool.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32_MAX = 2**31 - 1


def lex_min_select(cur_d: jax.Array, cur_i: jax.Array, kk: int) -> tuple:
    """kk rounds of lexicographic (d, id) min-extraction over a
    [TB, W] candidate block — the in-VMEM selection stage shared by
    every fused score+select kernel (this module's raw-L2 kernel and
    kernels/pq_adc_select.py's ADC kernel). VPU reductions +
    where-masks only: no sort network, no gathers. Extracted slots are
    remasked to the (inf, -1) placeholder, so exhausted blocks emit
    exactly the placeholder the jnp oracles emit."""
    out_d, out_i = [], []
    for _ in range(kk):
        bd = jnp.min(cur_d, axis=1, keepdims=True)        # [TB, 1]
        tie = jnp.where(cur_d == bd, cur_i, jnp.int32(_I32_MAX))
        bi = jnp.min(tie, axis=1, keepdims=True)          # [TB, 1]
        out_d.append(bd)
        out_i.append(bi)
        hit = (cur_d == bd) & (cur_i == bi)
        cur_d = jnp.where(hit, jnp.inf, cur_d)
        cur_i = jnp.where(hit, -1, cur_i)
    return (jnp.concatenate(out_d, axis=1),
            jnp.concatenate(out_i, axis=1))


def _coop_topk_kernel(q_ref, rows_ref, rn_ref, ids_ref, outd_ref,
                      outi_ref, *, kk: int):
    rstep = pl.program_id(1)

    @pl.when(rstep == 0)
    def _init():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    q = q_ref[...].astype(jnp.float32)        # [TB, n]
    rows = rows_ref[...].astype(jnp.float32)  # [TR, n]
    rn = rn_ref[...].astype(jnp.float32)      # [TR, 1]
    ids = ids_ref[...]                        # [TR, 1] int32

    qn = jnp.sum(q * q, axis=1, keepdims=True)            # [TB, 1]
    cross = jax.lax.dot_general(
        q, rows, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # [TB, TR]
    d = jnp.maximum(qn - 2.0 * cross + rn[:, 0][None, :], 0.0)
    idv = ids[:, 0][None, :]                              # [1, TR]
    d = jnp.where(idv < 0, jnp.inf, d)
    idm = jnp.broadcast_to(idv, d.shape)

    # running selection ++ tile, then kk lex-min extractions
    cur_d = jnp.concatenate([outd_ref[...], d], axis=1)
    cur_i = jnp.concatenate([outi_ref[...], idm], axis=1)
    outd_ref[...], outi_ref[...] = lex_min_select(cur_d, cur_i, kk)


@functools.partial(jax.jit,
                   static_argnames=("kk", "tile_b", "tile_r",
                                    "interpret"))
def coop_score_select_pallas(
    q: jax.Array,          # [B, n] f32
    rows: jax.Array,       # [R, n] payload dtype
    row_norms: jax.Array,  # [R, 1] f32
    ids: jax.Array,        # [R, 1] int32, -1 = masked
    kk: int,
    *,
    tile_b: int = 128,
    tile_r: int = 256,
    interpret: bool = False,
) -> tuple:
    b, n = q.shape
    r = rows.shape[0]
    assert b % tile_b == 0 and r % tile_r == 0, (b, r, tile_b, tile_r)
    grid = (b // tile_b, r // tile_r)  # R innermost: sequential carry
    return pl.pallas_call(
        functools.partial(_coop_topk_kernel, kk=kk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_r, n), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, kk), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kk), jnp.float32),
            jax.ShapeDtypeStruct((b, kk), jnp.int32),
        ],
        interpret=interpret,
    )(q, rows, row_norms, ids.astype(jnp.int32))
