"""Train-step builder: loss -> grads -> (optional compression) -> update.

`build_train_step(cfg, opt_cfg, ...)` returns a pure function
(params, opt_state, batch, extras) -> (params, opt_state, metrics)
suitable for jax.jit with explicit in/out shardings (launch/dryrun.py)
or plain CPU execution (tests). Gradient accumulation runs as a
`lax.scan` over microbatches — activation memory scales with the
microbatch while keeping arithmetic identical (sum of grads); this is
also the straggler-tolerant step shape (uniform microbatch work).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod

from . import compress as compress_mod
from . import optimizer as opt_mod


def loss_and_grads(params, batch, cfg: ModelConfig):
    (loss, metrics), grads = jax.value_and_grad(
        model_mod.loss_fn, has_aux=True)(params, batch, cfg)
    return loss, metrics, grads


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_mod.OptConfig,
    *,
    grad_accum: int = 1,
    compression: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, batch [, error_state])."""

    def single(params, batch):
        _, metrics, grads = loss_and_grads(params, batch, cfg)
        return metrics, grads

    def accumulated(params, batch):
        # batch leaves [B, ...] -> [A, B/A, ...]
        def split(x):
            b = x.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            return x.reshape((grad_accum, b // grad_accum) + x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            acc, _ = carry
            metrics, grads = single(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, metrics), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, metrics), _ = jax.lax.scan(
            body, (zeros, {"loss": jnp.zeros((), jnp.float32),
                           "ntokens": jnp.zeros((), jnp.float32),
                           "ppl_proxy": jnp.zeros((), jnp.float32),
                           "moe_loss": jnp.zeros((), jnp.float32),
                           "total_loss": jnp.zeros((), jnp.float32)}),
            micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        return metrics, grads

    def train_step(params, opt_state, batch, error_state=None):
        if grad_accum > 1:
            metrics, grads = accumulated(params, batch)
        else:
            metrics, grads = single(params, batch)
        if compression:
            assert error_state is not None
            grads, error_state = compress_mod.ef_quantize(
                grads, error_state)
        params, opt_state, opt_metrics = opt_mod.apply(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        if compression:
            return params, opt_state, error_state, metrics
        return params, opt_state, metrics

    return train_step
