from . import checkpoint, compress, fault, optimizer, train_step

__all__ = ["checkpoint", "compress", "fault", "optimizer", "train_step"]
