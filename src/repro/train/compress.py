"""Error-feedback int8 gradient compression (cross-pod all-reduce trick).

At 2-pod scale the `pod` axis rides the slowest links; compressing the
cross-pod gradient reduction 4x (f32 -> int8 with per-tensor scale)
cuts the collective roofline term proportionally. Error feedback keeps
the quantization noise from biasing convergence: the residual e_t is
added back before the next quantization (Seide et al. / EF-SGD).

``compressed_psum`` performs the wire-honest collective inside
shard_map: quantize -> psum(int32) -> dequantize. ``simulate`` applies
the same quantize/dequantize semantics without a mesh (used to unit-test
convergence impact on CPU).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(grads, errors):
    """(grads + errors) -> (quantized-dequantized grads, new errors)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _quantize(gf)
        dq = _dequantize(q, s)
        return dq.astype(g.dtype), gf - dq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_state(grads_like):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Wire-honest int8 all-reduce over `axis_name` (inside shard_map).

    A shared scale is agreed with a scalar max-reduce first, then the
    payload reduction is int8-quantized (int32 accumulate to avoid
    overflow at <=2^23 shards): wire bytes = N/4 + O(1) vs f32 psum.
    """
    xf = x.astype(jnp.float32)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
