"""Optimizers (no optax dependency): AdamW + SGDM, schedules, clipping.

Optimizer state mirrors the parameter pytree, so the same logical-axis
sharding rules shard it (ZeRO/FSDP falls out of `fsdp` rules for free).
``state_dtype`` trades optimizer-state memory for precision — the 405B
single-pod memory table in EXPERIMENTS.md uses bf16 moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 halves optimizer memory


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


class OptState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment  (pytree like params)
    nu: Any       # second moment (pytree like params; zeros for sgdm)


def init(cfg: OptConfig, params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(
    cfg: OptConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        if cfg.name == "adamw":
            m1 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v1 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m1 / bc1
            vhat = v1 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (new_p.astype(p.dtype), m1.astype(cfg.state_dtype),
                    v1.astype(cfg.state_dtype))
        elif cfg.name == "sgdm":
            m1 = b1 * m.astype(jnp.float32) + gf
            new_p = p.astype(jnp.float32) - lr * m1
            return (new_p.astype(p.dtype), m1.astype(cfg.state_dtype), v)
        raise ValueError(cfg.name)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step, new_m, new_v), metrics
