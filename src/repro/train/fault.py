"""Fault-tolerant training supervisor: checkpoint/restart, injection.

The supervisor owns the loop: it checkpoints every `ckpt_every` steps,
catches step failures (device loss at pod scale; injected faults in
tests), restores the last durable state and replays forward — and
because the data pipeline is stateless-by-step, replay is bitwise
identical (asserted in tests/test_fault_tolerance.py). Straggling steps
are detected against an EMA budget and surfaced via metrics; elastic
rescale is handled at restore time by re-device_put'ing the full logical
tensors under the new mesh (see checkpoint.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro import obs
# FaultInjector moved to the SHARED repro.fault in PR 8 (serving
# injects faults through the same class — docs/FAULT.md); this
# re-export keeps the historic import path working
from repro.fault import FaultInjector  # noqa: F401
from repro.obs import now

from .checkpoint import Checkpointer


@dataclasses.dataclass
class Supervisor:
    train_step: Callable  # (params, opt_state, batch) -> (p, o, metrics)
    make_batch: Callable  # step -> batch
    ckpt: Checkpointer
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    injector: Optional[FaultInjector] = None
    max_restarts: int = 3

    def run(
        self, params, opt_state, start_step: int, num_steps: int,
        log_every: int = 10,
    ) -> Dict[str, Any]:
        step = start_step
        history: List[float] = []
        restarts = 0
        ema = None
        stragglers = 0
        while step < start_step + num_steps:
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                t0 = now()
                batch = self.make_batch(step)
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = now() - t0
                if ema is None:
                    ema = dt
                else:
                    if dt > self.straggler_factor * ema:
                        stragglers += 1
                        obs.REGISTRY.counter("train.stragglers").inc()
                    ema = 0.9 * ema + 0.1 * dt
                # replayed steps BELOW start_step (a restore point
                # that predates this run) are warm-up, not part of
                # this run's loss history
                if step >= start_step:
                    history.append(loss)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(
                        step, {"params": params, "opt_state": opt_state},
                        extra={"loss": loss})
            except Exception:  # noqa: BLE001 — restart on any fault
                restarts += 1
                obs.REGISTRY.counter("train.restarts").inc()
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    # restart from the provided initial state
                    step = start_step
                    history = []
                    continue
                self.ckpt.wait()
                latest, state, _ = self.ckpt.restore(
                    {"params": params, "opt_state": opt_state}, latest)
                params = state["params"]
                opt_state = state["opt_state"]
                # drop history past the restore point; clamped at 0 —
                # a checkpoint that PRECEDES start_step (left by an
                # earlier run of the same dir) used to make this slice
                # negative and silently truncate the tail instead
                history = history[:max(latest - start_step, 0)]
                step = latest
        self.ckpt.wait()
        return {
            "params": params,
            "opt_state": opt_state,
            "losses": history,
            "restarts": restarts,
            "stragglers": stragglers,
            "final_step": step,
        }
