"""Sharded, elastic, integrity-checked checkpointing (no orbax).

Layout:  <dir>/step_<N>/
            manifest.json   — step, rng, data cursor, config hash,
                              per-tensor {path, shape, dtype, sha256}
            <group>.npz     — top-level pytree groups, full logical
                              tensors (gathered from device shards)

Design points for the 1000-node story (DESIGN.md §5.5):
* Elastic restore: tensors are saved in logical (unsharded) form keyed
  by tree path, so restore simply device_puts with the *current* mesh's
  shardings — rescaling pods between runs is a pure reload. (At 405B you
  would save per-host shards; the manifest format already records shapes
  per tensor so a sharded writer is a drop-in change.)
* Async save: arrays are snapshotted to host then written by a
  background thread; the train loop never blocks on disk.
* Integrity: sha256 per file, validated on restore; a save is only
  visible once its manifest is atomically renamed into place.
* Retention: keep_last sweeps old steps.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


# npz cannot round-trip ml_dtypes (bf16 etc.) — store a raw-bits view
# and the true dtype name in the manifest, view back on restore.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(
        self, step: int, state: Dict[str, Any],
        extra: Optional[Dict[str, Any]] = None, *, sync: bool = False,
    ):
        """state: dict of top-level pytrees (params, opt_state, ...)."""
        # snapshot to host synchronously (cheap vs training step),
        # write asynchronously.
        snap = {g: _flatten_with_paths(t) for g, t in state.items()}
        self.wait()

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            manifest = {"step": step, "extra": extra or {}, "files": {}}
            for group, tensors in snap.items():
                fpath = os.path.join(tmp, f"{group}.npz")
                savable = {}
                dtypes = {}
                for k, v in tensors.items():
                    savable[k], dtypes[k] = _to_savable(v)
                np.savez(fpath, **savable)
                manifest["files"][group] = {
                    "sha256": _sha256(fpath),
                    "tensors": {k: [list(v.shape), dtypes[k]]
                                for k, v in tensors.items()},
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._sweep()

        if sync:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _sweep(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, templates: Dict[str, Any], step: Optional[int] = None,
        *, shardings: Optional[Dict[str, Any]] = None,
        validate: bool = True,
    ) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
        """templates: dict of pytrees giving structure. shardings:
        optional matching dict of sharding pytrees for elastic reload."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        base = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for group, template in templates.items():
            fpath = os.path.join(base, f"{group}.npz")
            if validate:
                want = manifest["files"][group]["sha256"]
                got = _sha256(fpath)
                if want != got:
                    raise IOError(
                        f"checkpoint corruption in {fpath}: "
                        f"sha256 {got} != {want}")
            data = np.load(fpath)
            leaves_p, treedef = jax.tree_util.tree_flatten_with_path(
                template)
            shard_flat = None
            if shardings and group in shardings:
                shard_flat = [
                    s for _, s in jax.tree_util.tree_flatten_with_path(
                        shardings[group])[0]]
            new = []
            tensor_meta = manifest["files"][group]["tensors"]
            for i, (path, _leaf) in enumerate(leaves_p):
                key = "/".join(
                    str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)
                arr = _from_savable(data[key], tensor_meta[key][1])
                if shard_flat is not None:
                    arr = jax.device_put(arr, shard_flat[i])
                else:
                    arr = jnp.asarray(arr)
                new.append(arr)
            out[group] = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), new)
        return step, out, manifest.get("extra", {})
