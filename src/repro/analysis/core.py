"""Framework for the repro static-analysis pass (docs/ANALYSIS.md).

The moving parts:

  Module    one parsed source file: AST + the comment map (tokenize)
            from which both ``# guarded_by: <lock>`` annotations and
            ``# repro: allow[rule-id] reason`` suppressions are read.
  Project   every module under analysis plus memoized CROSS-MODULE
            indexes (``Project.index``) — e.g. "functions whose body
            contains lax.while_loop" — so rules that need whole-
            program facts (the while-in-shard_map detector must see
            through engine.py -> search.py) share one collection pass.
  rule      registration decorator: a rule is a callable
            ``check(project) -> iterable[Finding]`` with a stable id;
            ``all_rules()`` imports :mod:`repro.analysis.rules` so
            registration happens on first use.
  run       applies rules, matches findings against allow comments,
            and turns allow HYGIENE violations into findings of their
            own: an allow that suppresses nothing, carries no reason,
            or names an unknown rule is an ``allow-hygiene`` error —
            suppressions must stay tethered to a live finding.

Suppression scope: an allow covers findings on its OWN line; an allow
on a comment-only line additionally covers the next code line (the
idiomatic "allow comment above the offending statement" placement).
Findings anchor at the statement's first line, so multi-line calls are
covered by an allow on the line the call starts.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Set, Tuple)

ALLOW_RE = re.compile(r"repro:\s*allow\[([A-Za-z0-9_-]+)\]\s*(.*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: where, which rule, why."""

    rule: str
    path: str
    line: int
    message: str
    severity: str = "error"

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity}: "
                f"[{self.rule}] {self.message}")


@dataclasses.dataclass
class Allow:
    """A parsed ``# repro: allow[rule-id] reason`` comment."""

    rule: str
    line: int
    reason: str
    used: bool = False


def _relname(path: str) -> str:
    """Repo-relative module path used for path-scoped rules (the
    clock rule exempts ``repro/obs/``): the part after ``src/`` when
    present, else the path as given (fixtures pass virtual repo-style
    paths directly)."""
    p = path.replace(os.sep, "/")
    if "/src/" in p:
        return p.split("/src/", 1)[1]
    if p.startswith("src/"):
        return p[len("src/"):]
    return p.lstrip("./")


class Module:
    """One parsed file: source, AST, comments, allows."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.relname = _relname(path)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        #: line -> full comment text (including the leading '#')
        self.comments: Dict[int, str] = {}
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                self.comments[tok.start[0]] = tok.string
        self.allows: List[Allow] = []
        #: (rule, covered-line) -> Allow
        self._allow_map: Dict[Tuple[str, int], Allow] = {}
        for line, text in sorted(self.comments.items()):
            m = ALLOW_RE.search(text)
            if not m:
                continue
            al = Allow(rule=m.group(1), line=line,
                       reason=m.group(2).strip())
            self.allows.append(al)
            self._allow_map[(al.rule, line)] = al
            if self.comment_only(line):
                self._allow_map[(al.rule, self._next_code_line(line))] = al

    def comment_only(self, line: int) -> bool:
        text = self.lines[line - 1] if line <= len(self.lines) else ""
        return text.lstrip().startswith("#")

    def _next_code_line(self, line: int) -> int:
        for i in range(line + 1, len(self.lines) + 1):
            text = self.lines[i - 1].strip()
            if text and not text.startswith("#"):
                return i
        return -1

    def allow_for(self, rule: str, line: int) -> Optional[Allow]:
        return self._allow_map.get((rule, line))

    def comment_in_range(self, lo: int, hi: int,
                         pattern: "re.Pattern") -> Optional["re.Match"]:
        """First comment between lines lo..hi (inclusive) matching
        ``pattern`` — how the guarded-by rule reads its trailing
        ``# guarded_by: <lock>`` annotations off multi-line statements."""
        for line in range(lo, hi + 1):
            text = self.comments.get(line)
            if text:
                m = pattern.search(text)
                if m:
                    return m
        return None


class Project:
    """All modules under analysis + shared memoized indexes."""

    def __init__(self, modules: Sequence[Module],
                 errors: Optional[List[Finding]] = None):
        self.modules = list(modules)
        self.errors: List[Finding] = list(errors or [])
        self._indexes: Dict[str, object] = {}

    @classmethod
    def from_paths(cls, paths: Iterable[str]) -> "Project":
        files: List[str] = []
        for p in paths:
            if os.path.isdir(p):
                for root, dirs, names in os.walk(p):
                    dirs[:] = sorted(d for d in dirs
                                     if d != "__pycache__")
                    files.extend(os.path.join(root, nm)
                                 for nm in sorted(names)
                                 if nm.endswith(".py"))
            else:
                files.append(p)
        mods, errors = [], []
        for f in files:
            with open(f, "r", encoding="utf-8") as fh:
                src = fh.read()
            try:
                mods.append(Module(f, src))
            except SyntaxError as e:
                errors.append(Finding(
                    "parse-error", f, e.lineno or 1,
                    f"could not parse: {e.msg}"))
        return cls(mods, errors)

    @classmethod
    def from_sources(cls, sources: Dict[str, str]) -> "Project":
        """In-memory project for rule fixtures: {virtual-path: source}.
        Paths should look repo-relative (``repro/x/y.py``) so
        path-scoped rules behave as they would on disk."""
        return cls([Module(p, s) for p, s in sources.items()])

    def index(self, key: str,
              build: Callable[["Project"], object]) -> object:
        if key not in self._indexes:
            self._indexes[key] = build(self)
        return self._indexes[key]


# ----------------------------------------------------------- rule registry
@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    check: Callable[[Project], Iterable[Finding]]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, summary: str):
    """Register a project-level rule under a stable kebab-case id."""

    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, summary, fn)
        return fn

    return deco


def all_rules() -> Dict[str, Rule]:
    from . import rules  # noqa: F401 — registers rules on import
    return dict(RULES)


# ------------------------------------------------------------- AST helpers
def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def terminal(dotted: Optional[str]) -> Optional[str]:
    """Last component of a dotted name ('jax.lax.top_k' -> 'top_k')."""
    return dotted.rsplit(".", 1)[-1] if dotted else None


def call_target(node: ast.Call) -> Optional[str]:
    return terminal(dotted_name(node.func))


def functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield n


def names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def assign_target_names(stmt: ast.stmt) -> Set[str]:
    """Plain-Name targets of an assignment, through tuple unpacking."""
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    out: Set[str] = set()
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                out.add(n.id)
    return out


def stmts_in_order(fn: ast.AST) -> Iterator[ast.stmt]:
    """Every statement under ``fn`` in source order, descending into
    compound bodies but NOT into nested function/class definitions —
    the unit of the intra-procedural taint rules."""
    body = getattr(fn, "body", [])
    stack = list(reversed(body))
    while stack:
        st = stack.pop()
        yield st
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        children: List[ast.stmt] = []
        for field in ("body", "orelse", "finalbody"):
            children.extend(getattr(st, field, []))
        for h in getattr(st, "handlers", []):
            children.extend(h.body)
        stack.extend(reversed(children))


# ------------------------------------------------------------------ runner
@dataclasses.dataclass
class Report:
    findings: List[Finding]                  # unsuppressed + hygiene
    suppressed: List[Tuple[Finding, Allow]]
    rules_run: List[str]

    @property
    def ok(self) -> bool:
        return not self.findings


def run(project: Project,
        rule_ids: Optional[Sequence[str]] = None) -> Report:
    rules = all_rules()
    if rule_ids is None:
        ids = sorted(rules)
    else:
        unknown = [r for r in rule_ids if r not in rules]
        if unknown:
            raise KeyError(f"unknown rule id(s): {unknown}")
        ids = list(rule_ids)
    raw: List[Finding] = list(project.errors)
    for rid in ids:
        raw.extend(rules[rid].check(project))
    mods = {m.path: m for m in project.modules}
    keep: List[Finding] = []
    suppressed: List[Tuple[Finding, Allow]] = []
    for f in raw:
        mod = mods.get(f.path)
        al = mod.allow_for(f.rule, f.line) if mod else None
        if al is not None:
            al.used = True
            suppressed.append((f, al))
        else:
            keep.append(f)
    # allow hygiene: every allow must name a real rule, give a reason,
    # and actually suppress something
    for mod in project.modules:
        for al in mod.allows:
            if al.rule not in rules:
                keep.append(Finding(
                    "allow-hygiene", mod.path, al.line,
                    f"allow names unknown rule {al.rule!r}"))
            elif al.rule not in ids:
                continue  # rule not run this pass: usage unknowable
            elif not al.reason:
                keep.append(Finding(
                    "allow-hygiene", mod.path, al.line,
                    f"allow[{al.rule}] without a reason — say why the "
                    "finding is acceptable"))
            elif not al.used:
                keep.append(Finding(
                    "allow-hygiene", mod.path, al.line,
                    f"unused allow[{al.rule}]: suppresses no finding "
                    "(stale after a fix? delete it)"))
    keep.sort(key=lambda f: (f.path, f.line, f.rule))
    suppressed.sort(key=lambda p: (p[0].path, p[0].line, p[0].rule))
    return Report(keep, suppressed, ids)
