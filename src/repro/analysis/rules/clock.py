"""clock-discipline: one monotonic clock for every duration.

``obs.now`` (= time.perf_counter, defined once in repro/obs/trace.py)
is THE clock of the repo: queue-wait arithmetic subtracts stamps taken
in different modules, so any module reading its own clock re-creates
the PR 6 serve bug (time.monotonic in batching vs perf_counter in
launch/serve made the subtraction incoherent). Outside ``repro/obs/``
no module may read a clock directly — flag both ``time.<clock>()``
attribute reads and ``from time import <clock>``. ``time.sleep`` is
not a clock read and stays legal.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .. import core
from ..core import Finding, Project

CLOCKS = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns", "perf_counter",
    "perf_counter_ns", "process_time", "process_time_ns",
})
EXEMPT_PREFIX = "repro/obs/"


@core.rule("clock-discipline",
           "no direct time.* clock reads outside repro/obs (use obs.now)")
def check(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        if mod.relname.startswith(EXEMPT_PREFIX):
            continue
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "time"
                    and node.attr in CLOCKS):
                yield Finding(
                    "clock-discipline", mod.path, node.lineno,
                    f"direct clock read time.{node.attr} — use "
                    "repro.obs.now so every duration is on the one "
                    "monotonic clock")
            elif (isinstance(node, ast.ImportFrom)
                    and node.module == "time"):
                for alias in node.names:
                    if alias.name in CLOCKS:
                        yield Finding(
                            "clock-discipline", mod.path, node.lineno,
                            f"'from time import {alias.name}' — use "
                            "repro.obs.now instead of a private clock")
