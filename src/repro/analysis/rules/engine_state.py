"""engine-stats: per-query engine state must travel on the result.

PR 9 removed ``DistributedEngine.last_ooc_stats``: a mutable
per-query field on a shared engine misattributes stats the moment two
``query()`` calls run concurrently (the continuous-batching front has
one in flight per guarantee lane), and the serving code that read it
after ``query`` returned raced exactly that way
(serve/batching.run_retrieval). Stats now ride the returned
``QueryResult.stats``. This rule keeps the old channel from growing
back: ANY attribute access spelled ``.last_ooc_stats`` — read, write,
or getattr-by-name — outside ``repro/core/engine.py`` is an error,
and inside the engine too (the field is gone; the only allowed
mentions are docstrings). ``getattr(x, "last_ooc_stats", ...)`` is
caught as well: that spelling is how the race hid from review the
first time.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .. import core
from ..core import Finding, Project

FIELD = "last_ooc_stats"


@core.rule("engine-stats",
           "per-query engine state read through the removed "
           "last_ooc_stats channel instead of QueryResult.stats")
def check(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr == FIELD:
                yield Finding(
                    "engine-stats", mod.path, node.lineno,
                    f"'.{FIELD}' is a removed per-query mutable "
                    "engine channel — stats travel on the result "
                    "(core.engine.QueryResult.stats)")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id in ("getattr", "setattr", "hasattr")
                  and len(node.args) >= 2
                  and isinstance(node.args[1], ast.Constant)
                  and node.args[1].value == FIELD):
                yield Finding(
                    "engine-stats", mod.path, node.lineno,
                    f"{node.func.id}(..., '{FIELD}') reads the "
                    "removed per-query engine channel — use "
                    "QueryResult.stats on the returned result")
