"""guarantee-kwargs: ONE spelling for a query's guarantee.

The guarantee taxonomy is a first-class type (core/guarantees.py), and
since the streaming-ingest redesign (docs/INGEST.md) every search
entry point — ``search``, ``search_ooc``, ``engine.query`` — takes it
as one ``Guarantee`` object. The historical loose spelling
(``delta=``/``epsilon=``/``nprobe=`` kwargs) survives one release
behind an APIDeprecationWarning shim for external callers, but the
repo's OWN callers must not regress onto it: a caller mixing the two
spellings silently loses the validation + kind classification the
Guarantee carries, and the shim is scheduled to disappear. Flag any
call to an entry-point name passing a loose guarantee kwarg. The
internal ``search_impl``/``_host_refine`` layers legitimately take the
unpacked scalars (the object is unpacked exactly once, at the public
boundary) and are not entry-point names.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from .. import core
from ..core import Finding, Project

ENTRY_POINTS = frozenset({
    "search", "search_ooc", "search_with_guarantee", "query",
})
LOOSE = frozenset({"delta", "epsilon", "nprobe"})


def _callee(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


@core.rule("guarantee-kwargs",
           "search entry points take g=Guarantee(...), not loose "
           "delta=/epsilon=/nprobe= kwargs")
def check(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if _callee(node.func) not in ENTRY_POINTS:
                continue
            loose = sorted(kw.arg for kw in node.keywords
                           if kw.arg in LOOSE)
            if loose:
                yield Finding(
                    "guarantee-kwargs", mod.path, node.lineno,
                    f"{_callee(node.func)}() called with loose "
                    f"guarantee kwargs {loose} — pass one "
                    "g=Guarantee(...) (core.guarantees constructors; "
                    "deprecated shim is for external callers only, "
                    "docs/INGEST.md)")
