"""stats-schema: no new free-form dict stats where a typed schema
exists.

PR 6 replaced the ``last_ooc_stats`` free-form dict with the typed
``repro.obs.stats.OocStats`` schema precisely because ad-hoc dicts
drift (three views of the same counters disagreed). This rule keeps
that from regressing: outside ``repro/obs/``, a dict literal whose
string keys overlap ``OocStats`` field names in >= 3 places is a new
stats surface that should be the typed schema (or an extension of it)
instead. The field list is read from the live dataclass so the rule
tracks schema growth automatically.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import FrozenSet, Iterator

from .. import core
from ..core import Finding, Project

_MIN_OVERLAP = 3
EXEMPT_PREFIX = "repro/obs/"


def _schema_fields() -> FrozenSet[str]:
    from repro.obs.stats import OocStats
    return frozenset(f.name for f in dataclasses.fields(OocStats))


@core.rule("stats-schema",
           "free-form stats dicts duplicating the typed obs.stats "
           "schema")
def check(project: Project) -> Iterator[Finding]:
    fields = _schema_fields()
    for mod in project.modules:
        if mod.relname.startswith(EXEMPT_PREFIX):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Dict):
                continue
            keys = {k.value for k in node.keys
                    if isinstance(k, ast.Constant)
                    and isinstance(k.value, str)}
            overlap = sorted(keys & fields)
            if len(overlap) >= _MIN_OVERLAP:
                shown = ", ".join(overlap[:4])
                if len(overlap) > 4:
                    shown += ", ..."
                yield Finding(
                    "stats-schema", mod.path, node.lineno,
                    f"free-form dict duplicates {len(overlap)} typed "
                    f"OocStats fields ({shown}) — construct/extend "
                    "the typed schema instead (repro.obs.stats)")
