"""Rule modules — importing this package registers every rule with
:mod:`repro.analysis.core`. New rules: add a module here, decorate the
check with ``@core.rule(...)``, import it below, and give it a
positive + negative fixture in tests/test_analysis.py (the meta test
fails otherwise). docs/ANALYSIS.md is the catalog."""

from . import (broad_except, clock, engine_state,  # noqa: F401
               guarantee_kwargs, guarded_by, jax_traps, stats_schema)
