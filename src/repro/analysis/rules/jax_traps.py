"""jax/XLA trap detectors — the repo's hard-won pitfalls, mechanized.

  jax-while-shard-map   a lax.while_loop reachable from inside a
                        shard_map'ed closure. On the pinned jax 0.4.37
                        this MISCOMPILES under jit (inner or outer):
                        the refinement loop exits early with silently
                        wrong neighbors (ROADMAP pin notes). Detection
                        is cross-module: pass 1 collects every
                        function whose body lexically contains a
                        while_loop, pass 2 flags while_loops (and
                        calls to collected functions) inside closures
                        handed to shard_map.
  jax-topk-on-topk      a top_k whose operand derives from another
                        top_k. XLA:CPU rewrites a lone TopK to its
                        fast custom call but leaves a dependent TopK
                        as a full O(R log R) sort — measured ~70x
                        slower at cooperative width (docs/PERF.md).
                        Intra-procedural forward taint.
  jax-int32-topk        a top_k keyed on integer data: the int TopK
                        path is ~60x slower than f32 on XLA:CPU
                        (docs/PERF.md) — rank/bitcast the key into
                        f32 instead. Flags an int-cast in the operand
                        expression or one assignment upstream.
  jax-host-sync-in-jit  .item() / np.asarray / jax.debug.callback on
                        values derived from the parameters of a
                        function that is jitted (decorator, jax.jit(f)
                        call, or pallas_call kernel): a host sync on a
                        tracer either fails to trace or silently
                        serializes dispatch.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set

from .. import core
from ..core import Finding, Module, Project

_WHILE = "while_loop"
_TOPK = "top_k"
_INT_DTYPES = frozenset({
    "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
})


# ------------------------------------------------- while-in-shard_map
def _while_fns(project: Project) -> Set[str]:
    """Names of functions whose body lexically contains a
    lax.while_loop call (project-wide, name-keyed)."""
    out: Set[str] = set()
    for mod in project.modules:
        for fn in core.functions(mod.tree):
            for n in ast.walk(fn):
                if (isinstance(n, ast.Call)
                        and core.call_target(n) == _WHILE):
                    out.add(fn.name)
                    break
    return out


def _closure_of(call: ast.Call,
                local_fns: Dict[str, ast.FunctionDef]
                ) -> Optional[ast.AST]:
    """The function body handed to a shard_map(...) call, when it is
    resolvable in this module: a lambda, or a Name bound to a local
    def."""
    if not call.args:
        return None
    arg = call.args[0]
    if isinstance(arg, ast.Lambda):
        return arg
    if isinstance(arg, ast.Name):
        return local_fns.get(arg.id)
    return None


@core.rule("jax-while-shard-map",
           "lax.while_loop reachable inside a shard_map'ed closure "
           "(0.4.37 miscompile)")
def check_while_shard_map(project: Project) -> Iterator[Finding]:
    wf: Set[str] = project.index("while_fns", _while_fns)
    for mod in project.modules:
        local_fns = {f.name: f for f in core.functions(mod.tree)}
        for n in ast.walk(mod.tree):
            if not (isinstance(n, ast.Call)
                    and core.call_target(n) == "shard_map"):
                continue
            closure = _closure_of(n, local_fns)
            if closure is None:
                continue
            cname = getattr(closure, "name", "<lambda>")
            for c in ast.walk(closure):
                if not isinstance(c, ast.Call):
                    continue
                t = core.call_target(c)
                if t == _WHILE:
                    yield Finding(
                        "jax-while-shard-map", mod.path, c.lineno,
                        "lax.while_loop lexically inside the "
                        f"shard_map'ed closure '{cname}' — "
                        "miscompiles under jit on jax 0.4.37 "
                        "(ROADMAP pin notes): run the shard_map "
                        "eagerly or hoist the loop")
                elif t in wf and t != cname:
                    yield Finding(
                        "jax-while-shard-map", mod.path, c.lineno,
                        f"call to {t}() (contains lax.while_loop) "
                        f"inside the shard_map'ed closure '{cname}' "
                        "— miscompiles under jit on jax 0.4.37 "
                        "(ROADMAP pin notes): keep this call path "
                        "eager, or prove the pin moved")


# ------------------------------------------------------ topk-on-topk
def _topk_calls(node: ast.AST) -> List[ast.Call]:
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Call)
            and core.call_target(n) == _TOPK]


@core.rule("jax-topk-on-topk",
           "top_k operand derived from another top_k (XLA:CPU full-"
           "sort fallback)")
def check_topk_on_topk(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        for fn in core.functions(mod.tree):
            tainted: Set[str] = set()
            for st in core.stmts_in_order(fn):
                for call in _topk_calls(st):
                    if not call.args:
                        continue
                    operand = call.args[0]
                    dependent = (_topk_calls(operand)
                                 or core.names_in(operand) & tainted)
                    if dependent:
                        yield Finding(
                            "jax-topk-on-topk", mod.path, call.lineno,
                            "top_k operand derives from another "
                            "top_k: XLA:CPU lowers the dependent "
                            "TopK as a full O(R log R) sort, ~70x "
                            "slower (docs/PERF.md) — restructure to "
                            "a single TopK (see "
                            "_select_k_by_d_id_shared)")
                if isinstance(st, (ast.Assign, ast.AugAssign,
                                   ast.AnnAssign)) and st.value:
                    if (_topk_calls(st.value)
                            or core.names_in(st.value) & tainted):
                        tainted |= core.assign_target_names(st)


# -------------------------------------------------------- int32-topk
def _has_int_cast(node: ast.AST) -> bool:
    """True if the expression subtree contains an integer-dtype cast:
    x.astype(jnp.int32) / x.astype("int32") / jnp.int32(x) /
    asarray(x, jnp.int32) and friends."""
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        t = core.call_target(n)
        if t in _INT_DTYPES:
            return True
        if t in ("astype", "asarray", "array", "full", "zeros",
                 "ones", "arange"):
            cands = list(n.args) + [kw.value for kw in n.keywords
                                    if kw.arg == "dtype"]
            for a in cands:
                if core.terminal(core.dotted_name(a)) in _INT_DTYPES:
                    return True
                if (isinstance(a, ast.Constant)
                        and a.value in _INT_DTYPES):
                    return True
    return False


@core.rule("jax-int32-topk",
           "top_k keyed on integer data (XLA:CPU int TopK ~60x "
           "slower than f32)")
def check_int_topk(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        for fn in core.functions(mod.tree):
            assigns: Dict[str, List[ast.expr]] = {}
            for st in core.stmts_in_order(fn):
                for call in _topk_calls(st):
                    if not call.args:
                        continue
                    operand = call.args[0]
                    inty = _has_int_cast(operand)
                    if not inty and isinstance(operand, ast.Name):
                        inty = any(_has_int_cast(v) for v
                                   in assigns.get(operand.id, []))
                    if inty:
                        yield Finding(
                            "jax-int32-topk", mod.path, call.lineno,
                            "top_k keyed on an integer operand: the "
                            "int TopK path is ~60x slower than f32 "
                            "on XLA:CPU (docs/PERF.md) — rank or "
                            "bitcast the key into f32")
                if isinstance(st, (ast.Assign, ast.AnnAssign)) \
                        and st.value is not None:
                    for nm in core.assign_target_names(st):
                        assigns.setdefault(nm, []).append(st.value)


# -------------------------------------------------- host-sync-in-jit
def _jitted_fns(project: Project) -> Set[str]:
    """Names of functions that run as traced bodies: decorated with
    jit (directly or via functools.partial), passed to a jax.jit(...)
    call, or handed to pallas_call as the kernel."""
    names: Set[str] = set()
    for mod in project.modules:
        for n in ast.walk(mod.tree):
            if not isinstance(n, ast.Call):
                continue
            t = core.call_target(n)
            if t in ("jit", "pallas_call") and n.args:
                arg = n.args[0]
                if (isinstance(arg, ast.Call)
                        and core.call_target(arg) == "partial"
                        and arg.args):
                    arg = arg.args[0]
                nm = core.terminal(core.dotted_name(arg))
                if nm:
                    names.add(nm)
        for fn in core.functions(mod.tree):
            for dec in fn.decorator_list:
                dt = core.terminal(core.dotted_name(dec))
                if dt == "jit":
                    names.add(fn.name)
                elif isinstance(dec, ast.Call):
                    ct = core.call_target(dec)
                    if ct == "jit":
                        names.add(fn.name)
                    elif ct == "partial" and dec.args and \
                            core.terminal(core.dotted_name(
                                dec.args[0])) == "jit":
                        names.add(fn.name)
    return names


_SYNC_NP = frozenset({"np.asarray", "numpy.asarray", "onp.asarray"})


def _param_names(fn: ast.AST) -> Set[str]:
    a = fn.args
    out = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        out.add(a.vararg.arg)
    if a.kwarg:
        out.add(a.kwarg.arg)
    return out


def _check_jit_body(mod: Module, fn: ast.FunctionDef
                    ) -> Iterator[Finding]:
    tainted = _param_names(fn)
    for st in core.stmts_in_order(fn):
        for n in ast.walk(st):
            if not isinstance(n, ast.Call):
                continue
            d = core.dotted_name(n.func)
            t = core.terminal(d)
            if (t == "item" and isinstance(n.func, ast.Attribute)
                    and core.names_in(n.func.value) & tainted):
                yield Finding(
                    "jax-host-sync-in-jit", mod.path, n.lineno,
                    f".item() on a traced value inside jitted "
                    f"'{fn.name}' — host sync on a tracer")
            elif d in _SYNC_NP and n.args \
                    and core.names_in(n.args[0]) & tainted:
                yield Finding(
                    "jax-host-sync-in-jit", mod.path, n.lineno,
                    f"np.asarray on a traced value inside jitted "
                    f"'{fn.name}' — host sync on a tracer (use "
                    "jnp.asarray)")
            elif d is not None and d.endswith("debug.callback"):
                yield Finding(
                    "jax-host-sync-in-jit", mod.path, n.lineno,
                    f"jax.debug.callback inside jitted '{fn.name}' "
                    "— a host round-trip per call; keep it out of "
                    "hot traced bodies")
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)) \
                and getattr(st, "value", None) is not None \
                and core.names_in(st.value) & tainted:
            tainted |= core.assign_target_names(st)


@core.rule("jax-host-sync-in-jit",
           "host sync (.item / np.asarray / debug.callback) on "
           "tracers inside jit/pallas bodies")
def check_host_sync(project: Project) -> Iterator[Finding]:
    jitted: Set[str] = project.index("jitted_fns", _jitted_fns)
    for mod in project.modules:
        for fn in core.functions(mod.tree):
            if fn.name in jitted:
                yield from _check_jit_body(mod, fn)
