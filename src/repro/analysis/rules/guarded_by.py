"""guarded-by: lock-discipline checking for annotated fields.

Convention (docs/ANALYSIS.md): a field assignment in a class carrying
a trailing ``# guarded_by: <lock>`` comment declares that every OTHER
``self.<field>`` read/write in that class must sit lexically inside
``with self.<lock>:``. ``__init__`` is exempt (the object is not yet
shared). The check is class-scoped and lexical — accesses from outside
the class, or through an alias, are invisible; the annotation is a
contract for the class's own methods, which is where the prefetcher's
"mutated ONLY under self._lock" comment lived unchecked.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Set

from .. import core
from ..core import Finding, Module, Project

GUARD_RE = re.compile(r"#\s*guarded_by:\s*([A-Za-z_][A-Za-z0-9_]*)")


def _declared(mod: Module, cls: ast.ClassDef) -> Dict[str, str]:
    """{field: lock} from annotated ``self.<field> = ...`` statements."""
    out: Dict[str, str] = {}
    for node in ast.walk(cls):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        fields = [t.attr for t in targets
                  if isinstance(t, ast.Attribute)
                  and isinstance(t.value, ast.Name)
                  and t.value.id == "self"]
        if not fields:
            continue
        m = mod.comment_in_range(
            node.lineno, node.end_lineno or node.lineno, GUARD_RE)
        if m:
            for f in fields:
                out[f] = m.group(1)
    return out


def _check_fn(mod: Module, fn: ast.AST, guarded: Dict[str, str],
              out: List[Finding]) -> None:
    def walk(node: ast.AST, held: Set[str]) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            newly = set()
            for item in node.items:
                d = core.dotted_name(item.context_expr)
                if d and d.startswith("self."):
                    newly.add(d[len("self."):])
                walk(item.context_expr, held)
                if item.optional_vars is not None:
                    walk(item.optional_vars, held)
            for st in node.body:
                walk(st, held | newly)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guarded
                and guarded[node.attr] not in held):
            out.append(Finding(
                "guarded-by", mod.path, node.lineno,
                f"'self.{node.attr}' is guarded_by "
                f"'{guarded[node.attr]}' but accessed outside "
                f"'with self.{guarded[node.attr]}:'"))
        for child in ast.iter_child_nodes(node):
            walk(child, held)

    for st in getattr(fn, "body", []):
        walk(st, set())


@core.rule("guarded-by",
           "annotated fields only touched under their declared lock")
def check(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        for cls in [n for n in ast.walk(mod.tree)
                    if isinstance(n, ast.ClassDef)]:
            guarded = _declared(mod, cls)
            if not guarded:
                continue
            findings: List[Finding] = []
            for item in cls.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name == "__init__":
                    continue  # not yet shared across threads
                _check_fn(mod, item, guarded, findings)
            yield from findings
