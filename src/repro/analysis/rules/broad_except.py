"""broad-except: every ``except Exception`` must say WHY.

A broad handler (bare ``except``, ``except Exception``, ``except
BaseException``, or a tuple containing one) is sometimes exactly right
— a failover boundary, a supervisor restart loop, a daemon thread's
last line of defence — and sometimes a bug magnet that silently eats
``KeyError`` from three frames down. The difference is whether the
author can articulate the boundary, so this rule makes the
articulation mandatory: the handler line must carry a comment giving a
REASON, or the site must be suppressed with
``# repro: allow[broad-except] reason`` (the allow's reason is
enforced by the allow-hygiene pass). A bare ``# noqa: BLE001`` with no
prose does not count — it silences a linter, it does not explain the
boundary.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from .. import core
from ..core import ALLOW_RE, Finding, Project

BROAD = frozenset({"Exception", "BaseException"})
# '# noqa', '# noqa: BLE001', '# noqa: BLE001,E501' — directive only,
# no explanation attached
_BARE_NOQA_RE = re.compile(
    r"^#\s*noqa(?::\s*[A-Z][A-Z0-9]*(?:\s*,\s*[A-Z][A-Z0-9]*)*)?\s*$")


def _is_broad(type_node) -> bool:
    if type_node is None:  # bare except
        return True
    if isinstance(type_node, ast.Tuple):
        return any(_is_broad(e) for e in type_node.elts)
    return core.terminal(core.dotted_name(type_node)) in BROAD


def _has_reason(comment: str) -> bool:
    """True when the trailing comment carries actual prose: not empty,
    not a bare noqa directive, not (only) the allow marker itself —
    an allow is a suppression, and suppressions are matched by the
    runner so they stay tethered to a live finding."""
    if ALLOW_RE.search(comment):
        return False
    if _BARE_NOQA_RE.match(comment.strip()):
        return False
    text = comment.lstrip("#").strip()
    # strip a leading noqa directive and see if prose follows
    # ('# noqa: BLE001 — restart on any fault' is a reason)
    m = re.match(r"noqa(?::\s*[A-Z][A-Z0-9]*(?:\s*,\s*[A-Z][A-Z0-9]*)*)?"
                 r"\s*[-—:]*\s*(.*)", text)
    if m:
        text = m.group(1)
    return bool(text.strip())


@core.rule("broad-except",
           "except Exception sites must carry a reason comment")
def check(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node.type):
                continue
            comment = mod.comments.get(node.lineno)
            if comment is not None and _has_reason(comment):
                continue
            what = ("bare except" if node.type is None
                    else "except " + (core.dotted_name(node.type)
                                      or "Exception/..."))
            yield Finding(
                "broad-except", mod.path, node.lineno,
                f"{what} without a reason — add a trailing comment "
                "explaining the boundary (why EVERY failure stops "
                "here) or suppress with "
                "'# repro: allow[broad-except] reason'")
