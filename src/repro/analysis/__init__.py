"""repro.analysis — the repo-invariant static-analysis pass.

AST-based rules that mechanize invariants previously held by comments
and reviewer memory: lock discipline on annotated fields
(``guarded-by``), one-monotonic-clock discipline (``clock-
discipline``), the documented jax/XLA traps (``jax-while-shard-map``,
``jax-topk-on-topk``, ``jax-int32-topk``, ``jax-host-sync-in-jit``)
and typed-stats discipline (``stats-schema``).

    PYTHONPATH=src python -m repro.analysis src/

exits non-zero on any unsuppressed finding. Deliberate exceptions are
recorded in-line as ``# repro: allow[rule-id] reason`` — allows are
validated (no reason, unknown rule, or nothing to suppress is itself
an error). Rule catalog + annotation conventions: docs/ANALYSIS.md.
Pure stdlib: the pass needs no jax/numpy and runs over src/ in
seconds, so it gates CI ahead of every test job.
"""

from .core import (Allow, Finding, Module, Project, Report, Rule,
                   all_rules, rule, run)

__all__ = [
    "Allow", "Finding", "Module", "Project", "Report", "Rule",
    "all_rules", "rule", "run",
]
