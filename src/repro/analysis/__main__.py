"""CLI: ``python -m repro.analysis [paths...]`` (default: src/).

Prints one ``path:line: severity: [rule-id] message`` per unsuppressed
finding plus a summary line; exit status 1 on any finding (including
allow-hygiene violations), 0 on a clean pass. ``--rule`` restricts to
a subset; ``--list-rules`` prints the catalog ids."""

from __future__ import annotations

import argparse
import sys

from . import core


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-invariant static analysis (docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--rule", action="append", metavar="RULE-ID",
                    help="run only this rule (repeatable)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = core.all_rules()
    if args.list_rules:
        for rid in sorted(rules):
            print(f"{rid:24s} {rules[rid].summary}")
        return 0

    project = core.Project.from_paths(args.paths or ["src"])
    report = core.run(project, args.rule)
    for f in report.findings:
        print(f.format())
    print(f"repro.analysis: {len(report.rules_run)} rules over "
          f"{len(project.modules)} files — {len(report.findings)} "
          f"finding(s), {len(report.suppressed)} suppressed by allows")
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
