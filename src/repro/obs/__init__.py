"""repro.obs — query-plan tracing + metrics for the retrieval stack.

Two halves (docs/OBSERVABILITY.md):

  trace    opt-in span tracer (disabled by default, near-zero cost
           off): nestable context-manager spans on ONE monotonic
           clock (``obs.now``), per-query :class:`QueryProfile`
           summaries, Chrome trace-event JSON export.
  metrics  always-on process-wide registry of labeled counters /
           gauges / log-bucketed histograms (p50/p95/p99).

``OocStats`` is the typed per-query out-of-core telemetry schema both
halves share with the store/engine layer. ``lockorder`` is the
debug-mode lock-order recorder (wrap locks, run a workload,
``assert_acyclic()``) — the dynamic complement to the static
guarded-by pass in :mod:`repro.analysis`.
"""

from .lockorder import (LockOrderError, LockOrderRecorder, wrap
                        as wrap_lock)
from .metrics import (GROWTH, REGISTRY, Counter, Gauge, Histogram,
                      MetricsRegistry, registry)
from .stats import OocStats
from .trace import (NULL_SPAN, QueryProfile, Span, Tracer,
                    chrome_events, clear, disable, dump_chrome_trace,
                    enable, enabled, last_profile, now, profile, span,
                    tracer)

__all__ = [
    "GROWTH", "REGISTRY", "Counter", "Gauge", "Histogram",
    "LockOrderError", "LockOrderRecorder", "wrap_lock",
    "MetricsRegistry", "registry", "OocStats", "NULL_SPAN",
    "QueryProfile", "Span", "Tracer", "chrome_events", "clear",
    "disable", "dump_chrome_trace", "enable", "enabled",
    "last_profile", "now", "profile", "span", "tracer",
]
