"""Debug-mode lock-order recorder: deadlock prevention as a test
asset.

The static guarded-by pass (repro.analysis) proves each field is
touched under ITS lock; it cannot prove that two locks are always
taken in the same ORDER across threads — the classic AB/BA deadlock.
This module records the dynamic acquisition graph instead: wrap each
lock (``wrap(lock, "name")``), run a concurrent workload, then
``assert_acyclic()``. An edge a->b means some thread acquired b while
holding a; a cycle in that graph is a lock-order inversion — a
deadlock waiting for the right interleaving, even if this run never
hit it.

The wrapper is a delegating proxy, so Condition objects keep their
full interface (``wait``/``notify_all`` pass through ``__getattr__``);
re-entrant re-acquisition (RLock) records no self-edge. A
``Condition.wait`` releases and re-acquires its underlying lock
internally — invisible to the recorder, and harmless: a waiting
thread holds no OTHER recorder-visible lock transition while parked.

Debug-mode instrumentation: tests wrap the real prefetcher/cache
locks (tests/test_lockorder.py keeps cache._lock -> prefetcher._lock
acyclic as the serving surface grows multi-threaded); production code
paths never pay for it.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set

__all__ = ["LockOrderError", "LockOrderRecorder", "RECORDER", "wrap"]


class LockOrderError(AssertionError):
    """A cycle exists in the observed lock-acquisition graph."""


class _TrackedLock:
    """Delegating proxy around a Lock/RLock/Condition that reports
    acquire/release to its recorder. ``with`` works; everything not
    intercepted (wait, notify, locked, ...) passes through."""

    def __init__(self, recorder: "LockOrderRecorder", inner, name: str):
        self._recorder = recorder
        self._inner = inner
        self._name = name

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            self._recorder._on_acquire(self._name)
        return got

    def release(self):
        self._recorder._on_release(self._name)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __getattr__(self, attr):
        return getattr(self._inner, attr)

    def __repr__(self):
        return f"<tracked {self._name} {self._inner!r}>"


class LockOrderRecorder:
    """Collects held-before edges per thread; asserts acyclicity."""

    def __init__(self) -> None:
        self._meta = threading.Lock()   # guards _edges only
        self._edges: Dict[str, Set[str]] = {}  # guarded_by: _meta
        self._local = threading.local()

    # ------------------------------------------------------- recording
    def _held(self) -> List[str]:
        st = getattr(self._local, "held", None)
        if st is None:
            st = self._local.held = []
        return st

    def _on_acquire(self, name: str) -> None:
        held = self._held()
        new_edges = [h for h in held if h != name]
        if new_edges:
            with self._meta:
                for h in new_edges:
                    self._edges.setdefault(h, set()).add(name)
        held.append(name)

    def _on_release(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                break

    # ------------------------------------------------------------- API
    def wrap(self, lock, name: str) -> _TrackedLock:
        return _TrackedLock(self, lock, name)

    def edges(self) -> Dict[str, Set[str]]:
        with self._meta:
            return {a: set(bs) for a, bs in self._edges.items()}

    def find_cycle(self) -> Optional[List[str]]:
        """A lock-name cycle in the acquisition graph, or None. DFS
        with the standard white/grey/black coloring; the returned list
        starts and ends on the same name."""
        graph = self.edges()
        color: Dict[str, int] = {}      # 0 white, 1 grey, 2 black
        stack: List[str] = []

        def visit(node: str) -> Optional[List[str]]:
            color[node] = 1
            stack.append(node)
            for nxt in sorted(graph.get(node, ())):
                c = color.get(nxt, 0)
                if c == 1:
                    return stack[stack.index(nxt):] + [nxt]
                if c == 0:
                    cyc = visit(nxt)
                    if cyc:
                        return cyc
            stack.pop()
            color[node] = 2
            return None

        for start in sorted(graph):
            if color.get(start, 0) == 0:
                cyc = visit(start)
                if cyc:
                    return cyc
        return None

    def assert_acyclic(self) -> None:
        cyc = self.find_cycle()
        if cyc:
            raise LockOrderError(
                "lock-order inversion (potential deadlock): "
                + " -> ".join(cyc)
                + "; observed edges: "
                + "; ".join(f"{a}->{sorted(bs)}"
                            for a, bs in sorted(self.edges().items())))

    def clear(self) -> None:
        with self._meta:
            self._edges.clear()


#: process-wide default recorder (tests typically build private ones)
RECORDER = LockOrderRecorder()


def wrap(lock, name: str,
         recorder: Optional[LockOrderRecorder] = None) -> _TrackedLock:
    """Wrap ``lock`` so its acquisition order is recorded under
    ``name`` (in ``recorder`` or the process-wide default)."""
    return (recorder or RECORDER).wrap(lock, name)
