"""OocStats — THE typed per-query out-of-core telemetry schema.

Replaces the free-form dicts that used to flow out of
``search_ooc(...).stats`` and the engine (today:
``DistributedEngine.query(...)`` returns it on ``QueryResult.stats``;
the old mutable ``last_ooc_stats`` channel is gone — the
``engine-stats`` analysis rule fails any read of it):
every field is declared once here, the SAME instance feeds the span
tree (``search_ooc`` sets its fields as root-span attributes) and the
metrics registry, so the three views can never drift. Mapping-style
access (``stats["bytes_read"]``) is kept so existing call sites and
benches read it unchanged.

Field groups:

  cache/prefetch   byte and hit accounting from DeviceLeafCache +
                   LeafPrefetcher (registry-backed counters, windowed
                   per query by reset_counters()).
  refinement       what the host loop itself measured: iterations,
                   frontier refills, per-lane visit totals, which
                   stop condition fired per lane and the epsilon/delta
                   slack at stop (mean over lanes attributed to that
                   condition; slack = how far past the threshold the
                   stop fired, in squared-distance units).
  engine fold      ``shards`` holds the per-shard OocStats when the
                   DistributedEngine aggregates a cross-shard query.

Stop-condition attribution priority (a stopping lane can satisfy
several predicates at once): ``delta`` (the r_delta early stop — the
answer is already good enough) wins over ``epsilon`` (lb pruning — the
remaining leaves cannot improve it) wins over ``exhausted`` (rank
budget / scanned everything).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List


@dataclasses.dataclass
class OocStats:
    # ---- identity / knobs
    codec: str = ""
    share_gathers: bool = False
    prefetch_depth: int = 0
    # ---- cache / prefetcher accounting (DeviceLeafCache.stats())
    capacity_leaves: int = 0
    hits: int = 0
    hits_distinct: int = 0
    misses: int = 0
    hit_rate: float = 0.0
    hit_rate_distinct: float = 0.0
    bytes_read: int = 0          # TOTAL disk bytes incl. rerank + prefetch
    bytes_read_sync: int = 0     # demand-path reads only
    bytes_h2d: int = 0
    prefetch_hits: int = 0
    prefetch_bytes_read: int = 0
    prefetch_leaves_read: int = 0
    bytes_read_rerank: int = 0
    dataset_bytes: int = 0
    # ---- refinement-loop telemetry
    iterations: int = 0
    frontier_refills: int = 0    # lane-refill events across the loop
    leaves_visited: int = 0      # summed over lanes
    rows_scanned: int = 0        # candidates scored, summed over lanes
    pruning_ratio: float = 0.0   # 1 - leaves_visited / (lanes * L)
    stop_delta: int = 0          # lanes stopped by the r_delta early stop
    stop_epsilon: int = 0        # lanes stopped by (1+eps) lb pruning
    stop_exhausted: int = 0      # lanes that ran out of rank budget
    delta_slack: float = 0.0     # mean (1+eps)^2*rd^2 - bsf at delta stops
    eps_slack: float = 0.0       # mean next_lb*(1+eps)^2 - bsf at eps stops
    # ---- fault tolerance (engine fold; per-shard entries carry their
    # own retries/failovers, the degradation triple is engine-level —
    # docs/FAULT.md)
    retries: int = 0             # failed shard attempts that were retried
    failovers: int = 0           # shards served from a non-owner copy
    degraded: bool = False       # answer computed without >=1 shard
    shards_lost: int = 0
    effective_delta: float = 1.0  # honest delta of the returned answer
    # ---- engine cross-shard fold
    shards: List["OocStats"] = dataclasses.field(default_factory=list)

    # ------------------------------------------- dict-style back-compat
    def __getitem__(self, key: str):
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    def __contains__(self, key) -> bool:
        return isinstance(key, str) and hasattr(self, key)

    def keys(self):
        return [f.name for f in dataclasses.fields(self)]

    def items(self):
        return [(k, getattr(self, k)) for k in self.keys()]

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())

    def as_dict(self) -> dict:
        out = {k: v for k, v in self.items() if k != "shards"}
        out["shards"] = [s.as_dict() if isinstance(s, OocStats) else s
                         for s in self.shards]
        return out

    # --------------------------------------------------------- helpers
    _SUM_FIELDS = (
        "capacity_leaves", "hits", "hits_distinct", "misses",
        "bytes_read", "bytes_read_sync", "bytes_h2d", "prefetch_hits",
        "prefetch_bytes_read", "prefetch_leaves_read",
        "bytes_read_rerank", "dataset_bytes", "iterations",
        "frontier_refills", "leaves_visited", "rows_scanned",
        "stop_delta", "stop_epsilon", "stop_exhausted",
        "retries", "failovers",
    )

    @classmethod
    def aggregate(cls, per_shard: List["OocStats"]) -> "OocStats":
        """Cross-shard fold: sum the additive fields, recompute the
        hit rates from the summed counts, average the slacks weighted
        by the lanes attributed to each condition, keep the per-shard
        schemas under ``shards``."""
        agg = cls()
        if not per_shard:
            return agg
        agg.codec = per_shard[0].codec
        agg.share_gathers = per_shard[0].share_gathers
        agg.prefetch_depth = per_shard[0].prefetch_depth
        for s in per_shard:
            for f in cls._SUM_FIELDS:
                setattr(agg, f, getattr(agg, f) + s.get(f, 0))
        total = agg.hits + agg.misses
        distinct = agg.hits_distinct + agg.misses
        agg.hit_rate = agg.hits / total if total else 0.0
        agg.hit_rate_distinct = \
            agg.hits_distinct / distinct if distinct else 0.0
        for slack, n in (("delta_slack", "stop_delta"),
                         ("eps_slack", "stop_epsilon")):
            w = sum(s.get(n, 0) for s in per_shard)
            if w:
                setattr(agg, slack, sum(
                    s.get(slack, 0.0) * s.get(n, 0)
                    for s in per_shard) / w)
        # pruning ratio over the union of per-shard leaf populations:
        # mean of the per-shard ratios weighted by nothing is wrong
        # when shard sizes differ, so recompute from visit totals when
        # every shard filled the ratio field
        lanes_l = [s for s in per_shard if s.pruning_ratio or
                   s.leaves_visited]
        if lanes_l:
            agg.pruning_ratio = float(
                sum(s.pruning_ratio for s in per_shard) / len(per_shard))
        agg.shards = list(per_shard)
        return agg
