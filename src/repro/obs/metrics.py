"""Process-wide metrics registry: counters, gauges, log-bucketed
latency histograms with p50/p95/p99 extraction.

The registry is the always-on half of the observability layer (the
tracer is the opt-in half): host-side code increments labeled
counters/histograms unconditionally — each update is one dict-free
attribute op under a lock, nanoseconds against the ms-scale I/O and
device steps it measures. Metrics are keyed by (name, sorted labels);
the serving stack labels by guarantee kind / codec / shard so the
snapshot separates e.g. p99 retrieval latency per guarantee tier.

Histograms are log-bucketed: geometric bucket bounds with growth
``GROWTH`` (= 2^(1/8), ~9% relative resolution), an underflow bucket
for values <= ``lo``, exact min/max/count/sum tracked alongside.
Quantiles linearly interpolate inside the hit bucket and clamp to the
exact [min, max] — so any quantile is within one bucket (~9% relative)
of the true sample quantile, property-tested against numpy.quantile
in tests/test_obs.py.

Window semantics: counters are cumulative, but an owner that needs
per-query windows (DeviceLeafCache / LeafPrefetcher reset semantics)
calls ``mark()`` and reads ``since_mark`` — the registry keeps the
process-lifetime total either way, so per-instance resets can never
erase fleet-level accounting.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Optional, Tuple

GROWTH = 2.0 ** 0.125          # ~9.05% geometric bucket width
_LN_GROWTH = math.log(GROWTH)
_LO = 1e-9                     # first positive bucket upper bound
_N_BUCKETS = 480               # covers (1e-9, ~1e9] + underflow at [0]


class Counter:
    """Monotonic counter with an owner-managed window mark."""

    __slots__ = ("name", "labels", "_lock", "_value", "_mark")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0                           # guarded_by: _lock
        self._mark = 0                            # guarded_by: _lock

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        """Cumulative process-lifetime total."""
        # repro: allow[guarded-by] deliberate lock-free monitoring read: a single int load is atomic under the GIL and this sits on snapshot()/bench hot paths
        return self._value

    def mark(self) -> None:
        """Start a new measurement window (owner-private)."""
        with self._lock:
            self._mark = self._value

    @property
    def since_mark(self):
        # repro: allow[guarded-by] deliberate lock-free read: worst case is a window view one inc() stale, never torn — both fields are GIL-atomic ints
        return self._value - self._mark


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self._value = 0.0

    def set(self, v) -> None:
        self._value = v

    @property
    def value(self):
        return self._value


class Histogram:
    """Log-bucketed value histogram with quantile extraction.

    Bucket i > 0 spans (lo*G^(i-1), lo*G^i]; bucket 0 is the
    underflow [<= lo], including zeros. ``quantile(q)`` returns the
    value at fractional rank q*(count-1): walk cumulative bucket
    counts, linear-interpolate inside the hit bucket, clamp to the
    exact tracked [min, max].
    """

    __slots__ = ("name", "labels", "_lock", "_counts", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._counts = [0] * _N_BUCKETS           # guarded_by: _lock
        self.count = 0                            # guarded_by: _lock
        self.sum = 0.0                            # guarded_by: _lock
        self.min = math.inf                       # guarded_by: _lock
        self.max = -math.inf                      # guarded_by: _lock

    @staticmethod
    def _bucket(v: float) -> int:
        if v <= _LO:
            return 0
        i = int(math.log(v / _LO) / _LN_GROWTH) + 1
        return min(i, _N_BUCKETS - 1)

    @staticmethod
    def _bounds(i: int) -> Tuple[float, float]:
        if i == 0:
            return 0.0, _LO
        return _LO * GROWTH ** (i - 1), _LO * GROWTH ** i

    def record(self, v) -> None:
        v = float(v)
        with self._lock:
            self._counts[self._bucket(v)] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def quantile(self, q: float) -> float:
        with self._lock:
            if self.count == 0:
                return math.nan
            rank = q * (self.count - 1)
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if cum + c > rank:
                    lo, hi = self._bounds(i)
                    frac = (rank - cum + 0.5) / c
                    v = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                    return min(max(v, self.min), self.max)
                cum += c
            return self.max

    def quantiles(self, qs=(0.5, 0.95, 0.99)) -> Dict[str, float]:
        return {f"p{round(q * 100) if q < 1 else 100}":
                self.quantile(q) for q in qs}

    @property
    def mean(self) -> float:
        # sum and count must be read atomically TOGETHER — a record()
        # landing between the two loads skews the ratio (caught by the
        # guarded-by pass when these fields were annotated)
        with self._lock:
            return self.sum / self.count if self.count else math.nan

    def snapshot(self) -> Dict[str, float]:
        # scalar fields under one lock hold (no torn multi-field
        # read); quantiles() re-acquires per call, outside the hold
        with self._lock:
            n = self.count
            out = {"count": n, "sum": self.sum,
                   "min": self.min if n else math.nan,
                   "max": self.max if n else math.nan,
                   "mean": self.sum / n if n else math.nan}
        out.update(self.quantiles())
        return out


class MetricsRegistry:
    """Get-or-create registry keyed by (name, sorted label kv-pairs).
    One process-wide instance (``REGISTRY``); tests may build private
    ones or call :meth:`reset`."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[tuple, object] = {}   # guarded_by: _lock

    def _get(self, cls, name: str, labels: dict):
        lbl = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        key = (name, lbl)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, lbl)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def collect(self, prefix: Optional[str] = None):
        """All registered metric objects, optionally name-filtered."""
        with self._lock:
            ms = list(self._metrics.values())
        if prefix is not None:
            ms = [m for m in ms if m.name.startswith(prefix)]
        return ms

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, object]:
        """Flat {\"name{k=v,...}\": value-or-quantile-dict} view."""
        out: Dict[str, object] = {}
        for m in self.collect(prefix):
            lbl = ",".join(f"{k}={v}" for k, v in m.labels)
            key = f"{m.name}{{{lbl}}}" if lbl else m.name
            out[key] = m.snapshot() if isinstance(m, Histogram) \
                else m.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return REGISTRY
