"""Lightweight thread-safe span tracer for the retrieval stack.

One monotonic clock (``now`` = ``time.perf_counter``) stamps every
span; the SAME clock is exported to the serving front
(serve/batching.Request.submitted_at, launch/serve timings), so
queue-wait arithmetic across modules is coherent by construction —
never mix this with ``time.monotonic()`` or wall-clock time.

Tracing is DISABLED by default and near-zero cost when disabled:
:func:`span` returns a shared no-op context manager without touching
the tracer, so instrumented hot paths pay one module-global bool check
plus an empty ``with`` block (~sub-µs; measured as the
``obs_span_disabled_overhead`` row in benchmarks/bench_kernels.py,
< 5% of the cheapest merge kernel's call time).

When enabled, spans nest through a thread-local stack (each thread
builds its own subtree; ids are process-unique), finished spans land
in the tracer's ordered list, and two consumers read them:

  QueryProfile        a structured per-query summary of one span's
                      subtree: phase durations aggregated by child
                      name, plus ``total(attr)`` folds over numeric
                      span attributes (the obs smoke asserts
                      ``total("bytes_read")`` equals the cache +
                      prefetcher counters bit-exact).
  dump_chrome_trace   the same spans as Chrome trace-event JSON
                      (chrome://tracing, Perfetto) — ``ph="X"``
                      complete events, µs timestamps, span attrs in
                      ``args``.

Span taxonomy and attribute names are documented in
docs/OBSERVABILITY.md.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

#: THE monotonic clock of the whole serving stack (satellite: was
#: time.monotonic in serve/batching vs time.perf_counter in
#: launch/serve — queue-wait subtraction across the two was
#: incoherent).
now = time.perf_counter

_enabled = False


def enabled() -> bool:
    """Fast global flag — the only cost tracing adds when off."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


class _NullSpan:
    """Shared do-nothing span: what :func:`span` hands out while
    tracing is disabled. Accepts the full Span surface so call sites
    never branch."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass

    def add(self, key: str, n) -> None:
        pass


NULL_SPAN = _NullSpan()


@dataclasses.dataclass
class Span:
    """One timed region. Context-manager: ``with tracer.span(...) as
    sp: sp.set(bytes_read=...)``. ``t0``/``t1`` are ``now()`` stamps;
    ``parent`` is the enclosing span's id (-1 at a thread's root)."""

    name: str
    id: int = -1
    parent: int = -1
    t0: float = 0.0
    t1: float = 0.0
    tid: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _tracer: Optional["Tracer"] = dataclasses.field(
        default=None, repr=False, compare=False)

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def add(self, key: str, n) -> None:
        self.attrs[key] = self.attrs.get(key, 0) + n

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    @property
    def duration_ms(self) -> float:
        return (self.t1 - self.t0) * 1e3

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = now()
        self._tracer._pop(self)
        return False


class Tracer:
    """Collects finished spans. Thread-safe: each thread nests through
    its own stack; the finished list and the id counter are shared
    under one lock."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._ids = itertools.count()             # guarded_by: _lock
        self._spans: List[Span] = []              # guarded_by: _lock
        self._local = threading.local()

    # ------------------------------------------------------- internals
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, sp: Span) -> None:
        st = self._stack()
        sp.parent = st[-1].id if st else -1
        sp.t0 = now()
        st.append(sp)

    def _pop(self, sp: Span) -> None:
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        else:  # mis-nested exit: drop it from wherever it sits
            try:
                st.remove(sp)
            except ValueError:
                pass
        with self._lock:
            self._spans.append(sp)

    # ------------------------------------------------------------- API
    def span(self, name: str, **attrs) -> Span:
        with self._lock:
            sid = next(self._ids)
        return Span(name=name, id=sid, tid=threading.get_ident(),
                    attrs=dict(attrs), _tracer=self)

    def current(self) -> Optional[Span]:
        """The innermost OPEN span on this thread (None outside any)."""
        st = self._stack()
        return st[-1] if st else None

    def spans(self) -> List[Span]:
        """Finished spans, completion-ordered (children before their
        parent — a parent exits last)."""
        with self._lock:
            return list(self._spans)

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans() if s.name == name]

    def last(self, name: str) -> Optional[Span]:
        hits = self.find(name)
        return hits[-1] if hits else None

    def subtree(self, root: Span) -> List[Span]:
        """root + every finished descendant, completion-ordered."""
        all_spans = self.spans()
        keep = {root.id}
        # completion order puts children BEFORE parents, so walk the
        # list backwards: every span's parent is seen first
        out = []
        for sp in reversed(all_spans):
            if sp.id in keep or sp.parent in keep:
                keep.add(sp.id)
                out.append(sp)
        out.reverse()
        return out

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()


_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER


def span(name: str, **attrs):
    """The instrumentation entry point: a real span when tracing is
    enabled, the shared no-op otherwise. ``with obs.span("x") as sp:``
    works identically in both states."""
    if not _enabled:
        return NULL_SPAN
    return _TRACER.span(name, **attrs)


def clear() -> None:
    _TRACER.clear()


# ------------------------------------------------------- QueryProfile
@dataclasses.dataclass
class QueryProfile:
    """Structured summary of one query's span subtree.

    ``phase_ms`` aggregates DIRECT children by name (the per-phase
    breakdown: filter / iterations / finalize, or queue-wait /
    generate / retrieval on the serving side); ``attrs`` are the root
    span's attributes; :meth:`total` folds a numeric attribute over
    the whole subtree (each span counted once)."""

    name: str
    duration_ms: float
    attrs: Dict[str, Any]
    phase_ms: Dict[str, float]
    spans: List[Span]

    def total(self, attr: str, default=0):
        out = default
        for sp in self.spans:
            v = sp.attrs.get(attr)
            if v is not None:
                out = out + v
        return out

    def count(self, name: str) -> int:
        return sum(1 for sp in self.spans if sp.name == name)


def profile(root: Span, trc: Optional[Tracer] = None) -> QueryProfile:
    """Build a QueryProfile from a FINISHED root span."""
    trc = trc or _TRACER
    spans = trc.subtree(root)
    phase: Dict[str, float] = {}
    for sp in spans:
        if sp.parent == root.id:
            phase[sp.name] = phase.get(sp.name, 0.0) + sp.duration_ms
    return QueryProfile(name=root.name, duration_ms=root.duration_ms,
                        attrs=dict(root.attrs), phase_ms=phase,
                        spans=spans)


def last_profile(name: str,
                 trc: Optional[Tracer] = None) -> Optional[QueryProfile]:
    """Profile of the most recent finished span with this name."""
    trc = trc or _TRACER
    root = trc.last(name)
    return profile(root, trc) if root is not None else None


# ------------------------------------------------------- chrome trace
def _json_safe(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:  # numpy scalars
        return v.item()
    except AttributeError:
        return str(v)


def chrome_events(spans: List[Span]) -> List[dict]:
    """Spans -> Chrome trace-event "complete" (ph=X) events. ts/dur in
    µs on the shared monotonic clock; attrs become ``args``."""
    pid = os.getpid()
    return [{
        "name": sp.name, "ph": "X", "pid": pid, "tid": sp.tid,
        "ts": sp.t0 * 1e6, "dur": max(sp.t1 - sp.t0, 0.0) * 1e6,
        "args": {k: _json_safe(v) for k, v in sp.attrs.items()},
    } for sp in spans]


def dump_chrome_trace(path: str,
                      trc: Optional[Tracer] = None) -> str:
    """Write every finished span as Chrome trace-event JSON (load in
    chrome://tracing or https://ui.perfetto.dev). Returns ``path``."""
    trc = trc or _TRACER
    doc = {"traceEvents": chrome_events(trc.spans()),
           "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
