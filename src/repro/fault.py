"""Shared fault injection — one injector for training AND serving.

Grown out of ``train/fault.py`` (whose ``FaultInjector`` knew only
"fail once at step N"): serving needs faults addressed at *injection
points* inside a query, not training steps. An injector holds a set of
rules; code under test calls ``check(point, shard=..., replica=...)``
at its fault points and the injector either does nothing, sleeps (a
slow-shard rule), or raises :class:`FaultInjected`. The serving-grade
points wired in this repo (docs/FAULT.md):

    gather   before a shard's leaf-gather I/O (store/ooc._host_refine)
    score    before a shard's device scoring step (same loop)
    shard    at the start of every shard serve attempt
             (serve/fault.serve_shard_with_failover) — ``kill_shard``
             arms a rule here to take a whole shard down

Rule semantics: ``after`` skips the first N matching checks (so a
kill lands MID-query, after real work happened), ``times`` bounds how
often the rule fires (``inf`` = permanently down), ``delay_s`` sleeps
instead of raising (slow shard / straggler). ``replica`` in a rule
matches the attempt-order position the failover loop passes to
``check`` — position 0 is whichever copy currently owns the shard, so
"kill the owner" is ``replica=0`` without knowing the rotation.

Every firing lands in the obs registry (``fault.injected{point,
shard}``) so chaos runs are auditable after the fact. The class is
thread-safe: the engine's concurrent shard owners share one injector,
and chaos tests arm rules from another thread mid-query.

``maybe_fail(step)`` keeps the training contract byte-for-byte
(fail once per step in ``fail_at``); ``train/fault.py`` re-exports
this class so existing imports keep working.
"""

from __future__ import annotations

import math
import threading
import time
from typing import List, Optional

from repro import obs


class FaultInjected(RuntimeError):
    """An armed fault rule fired at an injection point."""

    def __init__(self, point: str, shard: Optional[int] = None,
                 replica: Optional[int] = None):
        super().__init__(
            f"injected fault at point {point!r}"
            + (f" shard={shard}" if shard is not None else "")
            + (f" replica={replica}" if replica is not None else ""))
        self.point = point
        self.shard = shard
        self.replica = replica


class _Rule:
    """One armed fault (mutable counters guarded by the injector lock)."""

    __slots__ = ("point", "shard", "replica", "times", "after",
                 "delay_s", "exc")

    def __init__(self, point, shard, replica, times, after, delay_s,
                 exc):
        self.point = point
        self.shard = shard
        self.replica = replica
        self.times = times
        self.after = after
        self.delay_s = delay_s
        self.exc = exc

    def matches(self, point, shard, replica) -> bool:
        if self.point != "*" and self.point != point:
            return False
        if self.shard is not None and self.shard != shard:
            return False
        if self.replica is not None and self.replica != replica:
            return False
        return True


class FaultInjector:
    """Deterministic fault injection for tests and chaos smokes.

    Also carries the training contract: ``FaultInjector(fail_at=[12])``
    + ``maybe_fail(step)`` fails once per listed step, exactly as the
    original ``train/fault.py`` class did.
    """

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()
        self._lock = threading.Lock()
        self._rules: List[_Rule] = []  # guarded by _lock

    # -------------------------------------------------- training path
    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")

    # --------------------------------------------------- serving path
    def fail(self, point: str = "*", *, shard: Optional[int] = None,
             replica: Optional[int] = None, times: float = 1,
             after: int = 0, exc=FaultInjected) -> "FaultInjector":
        """Arm a raising rule: the next ``times`` matching checks
        (after skipping the first ``after``) raise ``exc``."""
        with self._lock:
            self._rules.append(_Rule(point, shard, replica,
                                     float(times), int(after), 0.0, exc))
        return self

    def kill_shard(self, shard: int, *, replica: Optional[int] = None,
                   after: int = 0) -> "FaultInjector":
        """Take a shard down permanently: every point on every copy
        (or only attempt position ``replica``) fails from the
        ``after``-th matching check on."""
        return self.fail("*", shard=shard, replica=replica,
                         times=math.inf, after=after)

    def delay(self, point: str = "gather", *,
              shard: Optional[int] = None,
              replica: Optional[int] = None, seconds: float = 0.05,
              times: float = math.inf,
              after: int = 0) -> "FaultInjector":
        """Arm a slow-shard rule: matching checks sleep instead of
        raising (pairs with RetryPolicy.attempt_deadline_s to test the
        timeout -> failover path)."""
        with self._lock:
            self._rules.append(_Rule(point, shard, replica,
                                     float(times), int(after),
                                     float(seconds), FaultInjected))
        return self

    def clear(self) -> None:
        with self._lock:
            self._rules.clear()

    def check(self, point: str, *, shard: Optional[int] = None,
              replica: Optional[int] = None) -> None:
        """Evaluate every armed rule at an injection point. Raising
        rules win over delay rules armed at the same point; a delay
        rule sleeps OUTSIDE the lock (concurrent shard owners share
        one injector — a sleeping shard must not block the others)."""
        sleep_s = 0.0
        fire: Optional[_Rule] = None
        with self._lock:
            for r in self._rules:
                if not r.matches(point, shard, replica) or r.times <= 0:
                    continue
                if r.after > 0:
                    r.after -= 1
                    continue
                r.times -= 1
                if r.delay_s > 0:
                    sleep_s = max(sleep_s, r.delay_s)
                elif fire is None:
                    fire = r
        if fire is not None:
            obs.REGISTRY.counter(
                "fault.injected", point=point,
                shard=str(shard if shard is not None else "-")).inc()
            raise fire.exc(point, shard, replica)
        if sleep_s > 0:
            obs.REGISTRY.counter(
                "fault.delayed", point=point,
                shard=str(shard if shard is not None else "-")).inc()
            time.sleep(sleep_s)
