import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks
# the device count at first init. Everything below may import jax.

import argparse  # noqa: E402
import json  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.obs import now  # noqa: E402
from repro.configs import (ARCH_IDS, SHAPES, get_config,  # noqa: E402
                           shape_applicable)
from repro.launch import analytic  # noqa: E402
from repro.launch import roofline as roof  # noqa: E402
from repro.launch import sharding as shard_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_mod  # noqa: E402
from repro.models import params as params_mod  # noqa: E402
from repro.train import optimizer as opt_mod  # noqa: E402
from repro.train.train_step import build_train_step  # noqa: E402

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell this lowers + compiles the
real entry point (train_step / prefill / decode_step) against the
production mesh with ShapeDtypeStruct stand-ins (zero allocation),
prints memory_analysis / cost_analysis, and writes the roofline report
consumed by EXPERIMENTS.md §Dry-run/§Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi \
        --arch llama3-405b --shape train_4k
"""

# per-arch microbatching for the train shape: keeps the remat carry
# (num_blocks x microbatch x seq x d_model) within HBM (DESIGN.md §5.4)
GRAD_ACCUM = {
    "llama3-405b": 8,
    "qwen1.5-110b": 8,
    "chameleon-34b": 8,
    "dbrx-132b": 8,
    "jamba-v0.1-52b": 4,
    "minitron-8b": 4,
    "deepseek-moe-16b": 4,
    "gemma2-2b": 4,
    "seamless-m4t-medium": 1,
    "mamba2-370m": 8,
}

# optimizer-state dtype: bf16 halves moments for the giants (§Dry-run
# memory table discusses the f32 alternative)
OPT_DTYPE = {
    "llama3-405b": jnp.bfloat16,
    "qwen1.5-110b": jnp.bfloat16,
    "dbrx-132b": jnp.bfloat16,
}


def _opt_cfg(arch: str) -> opt_mod.OptConfig:
    return opt_mod.OptConfig(state_dtype=OPT_DTYPE.get(arch, jnp.float32))


def lower_cell(
    arch: str, shape_name: str, mesh, *, rules_overrides=None,
    grad_accum: Optional[int] = None, donate: bool = True,
    arch_overrides=None, parallelism: str = "tp",
) -> Dict[str, Any]:
    """parallelism: 'tp' = tensor parallel over 'model' + fsdp over
    data axes (baseline); 'fsdp' = pure ZeRO-3 — every mesh axis is a
    data axis, weights gathered at use. The right choice is
    size-dependent: TP wire scales with tokens*d_model*layers, FSDP
    wire with grad_accum*params (§Perf B2)."""
    cfg = get_config(arch)
    if arch_overrides:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, **arch_overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}
    act_ctx = None
    if parallelism == "fsdp":
        from repro.models import sharding_utils as su

        all_axes = tuple(mesh.axis_names)
        rules_overrides = dict(rules_overrides or {})
        rules_overrides.update({
            "batch": all_axes, "fsdp": all_axes, "heads": None,
            "kv_heads": None, "head_dim": None, "mlp": None,
            "vocab": None, "experts": None, "ssm_inner": None,
        })
        act_ctx = su.use_act_map({
            "batch": all_axes, "heads": (), "kv_heads": (),
            "head_dim": (), "mlp": (), "experts": (), "ssm_inner": (),
            "vocab": (), "seq_model": (),
        })
        act_ctx.__enter__()
    rules = shard_lib.mesh_rules(mesh, rules_overrides)
    world = mesh.devices.size

    p_abs = shard_lib.abstract_params(cfg)
    p_sh = params_mod.shardings(model_mod.model_specs(cfg), rules, mesh)
    in_specs = model_mod.input_specs(cfg, shape)
    in_abs = params_mod.abstract(in_specs)
    in_sh = params_mod.shardings(in_specs, rules, mesh)

    t0 = now()
    if shape.kind == "train":
        ocfg = _opt_cfg(arch)
        accum = grad_accum if grad_accum is not None \
            else GRAD_ACCUM.get(arch, 1)
        step_fn = build_train_step(cfg, ocfg, grad_accum=accum)
        o_abs = shard_lib.abstract_opt_state(cfg, ocfg)
        o_sh = shard_lib.opt_shardings(cfg, ocfg, mesh, rules)
        fn = jax.jit(
            step_fn,
            in_shardings=(p_sh, o_sh, in_sh),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = fn.lower(p_abs, o_abs, in_abs)
    elif shape.kind == "prefill":
        fn = jax.jit(
            lambda params, batch: model_mod.prefill(params, batch, cfg),
            in_shardings=(p_sh, in_sh),
        )
        lowered = fn.lower(p_abs, in_abs)
    else:  # decode
        fn = jax.jit(
            lambda params, tokens, cache, pos: model_mod.decode_step(
                params, tokens, cache, pos, cfg),
            in_shardings=(p_sh, in_sh["tokens"], in_sh["cache"],
                          NamedSharding(mesh, P())),
            donate_argnums=(2,) if donate else (),
        )
        lowered = fn.lower(p_abs, in_abs["tokens"], in_abs["cache"],
                           in_abs["pos"])
    t_lower = now() - t0
    if act_ctx is not None:
        act_ctx.__exit__()

    t0 = now()
    compiled = lowered.compile()
    t_compile = now() - t0

    mf = roof.model_flops(cfg, shape, cfg.active_param_count())
    accum = (grad_accum if grad_accum is not None
             else GRAD_ACCUM.get(arch, 1))
    remat = (shape.kind == "train"
             and cfg.remat_policy == "nothing_saveable")
    af = analytic.flops_model(cfg, shape, grad_accum=accum, remat=remat)
    ocfg_b = _opt_cfg(arch)
    opt_bpp = 2 * jnp.dtype(ocfg_b.state_dtype).itemsize
    ab = analytic.bytes_model(
        cfg, shape, param_count=cfg.param_count(), grad_accum=accum,
        opt_bytes_per_param=opt_bpp, remat=remat)
    report = roof.roofline_report(
        compiled, world=world, model_flops_global=mf,
        analytic_flops_global=af["flops_global"],
        analytic_bytes_global=ab["bytes_global"],
        steps_hint=f"grad_accum={accum}"
        if shape.kind == "train" else shape.kind,
    )
    report.update({
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "lower_seconds": round(t_lower, 1),
        "compile_seconds": round(t_compile, 1),
        "total_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    })
    # the two required printouts
    print(compiled.memory_analysis())
    ca = compat.cost_analysis(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed")
           if k in ca})
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="one shape (default all)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--grad-accum", type=int, default=None)
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(
            ("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}"
                print(f"=== {mesh_name} :: {tag} ===", flush=True)
                try:
                    with mesh:
                        rep = lower_cell(arch, shape, mesh,
                                         grad_accum=args.grad_accum)
                except Exception as e:  # noqa: BLE001 — sweep must survive any one cell's lowering failure; the error lands in its report JSON
                    failures += 1
                    rep = {"arch": arch, "shape": shape,
                           "status": "failed", "error": str(e)[-2000:],
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"FAILED: {e}", flush=True)
                with open(os.path.join(outdir, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=2, default=str)
                if rep.get("status") == "ok":
                    t = rep["terms_seconds"]
                    print(
                        f"ok lower={rep['lower_seconds']}s "
                        f"compile={rep['compile_seconds']}s "
                        f"compute={t['compute']:.4f}s "
                        f"memory={t['memory']:.4f}s "
                        f"coll={t['collective']:.4f}s "
                        f"bottleneck={rep['bottleneck']} "
                        f"useful={rep['useful_flops_ratio']:.2f}",
                        flush=True)
                elif rep.get("status") == "skipped":
                    print(f"skipped: {rep['reason']}", flush=True)
    print(f"done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
