"""Roofline-term extraction from compiled dry-run artifacts (§Roofline).

Three terms per (arch x shape x mesh), all in seconds-per-step on the
TARGET hardware (TPU v5e-class constants; this container only compiles):

    compute    = HLO_FLOPs_per_device            / PEAK_FLOPS
    memory     = HLO_bytes_accessed_per_device   / HBM_BW
    collective = wire_bytes_per_device           / ICI_BW

`cost_analysis()` is per-device post-SPMD, so no chip division is needed
(the formula `global / (chips * peak)` is identical). Collective bytes
are NOT in cost_analysis: we parse `compiled.as_text()` (post-partition
HLO, local shapes), classify every collective op, read its replica group
size g, and apply ring-algorithm wire-byte estimates:

    all-reduce      2 * S * (g-1)/g      (reduce-scatter + all-gather)
    all-gather      R * (g-1)/g          (R = gathered result)
    reduce-scatter  R * (g-1)            (R = scattered result, in = R*g)
    all-to-all      S * (g-1)/g
    collective-permute  S

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active params
audits how much compiled compute is "useful" (catches remat waste).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional

# --- target hardware constants (TPU v5e-class, per chip) ---
PEAK_FLOPS = 197e12   # bf16
HBM_BW = 819e9        # bytes/s
ICI_BW = 50e9         # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(?P<dtype>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(",
)
_TUPLE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    op: str
    bytes_result: int
    group_size: int
    wire_bytes: float
    line: str


def _type_bytes(dtype: str, shape: str) -> int:
    nelem = 1
    if shape.strip():
        for d in shape.split(","):
            nelem *= int(d)
    return nelem * _DTYPE_BYTES.get(dtype, 4)


def _line_result_bytes(line: str) -> int:
    """Sum byte sizes of the result type(s) on an HLO op line.

    For async `-start` ops the result tuple carries (operand, result);
    we halve to avoid double counting."""
    lhs = line.split("=", 1)
    head = lhs[1] if len(lhs) > 1 else line
    # result types end before the op mnemonic
    m = re.search(r"\s(?:all-reduce|all-gather|reduce-scatter|"
                  r"all-to-all|collective-permute)", head)
    typepart = head[: m.start()] if m else head.split("(", 1)[0]
    total = 0
    for dtype, shape in _TUPLE_RE.findall(typepart):
        total += _type_bytes(dtype, shape)
    if "-start" in line and typepart.strip().startswith("("):
        total //= 2
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, size = int(m.group(1)), int(m.group(2))
        return max(size, 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([t for t in m.group(1).split(",") if t.strip()]),
                   1)
    return world


def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if op == "all-reduce":
        return 2.0 * result_bytes * frac
    if op == "all-gather":
        return result_bytes * frac
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-to-all":
        return result_bytes * frac
    if op == "collective-permute":
        return float(result_bytes)
    return float(result_bytes)


_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """HLO text -> {computation name: body lines}. Computation headers
    start at column 0 (body ops are indented); this is stable across
    XLA's text formats and robust to nested-paren parameter tuples."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line[:1] not in ("", " ", "}", ")"):
            m = _COMP_START_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None and line.startswith(" "):
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound heuristic: the largest integer constant compared in
    the condition computation (jax scans lower to a counted while)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _comp_multipliers(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Execution-count multiplier per computation: product of enclosing
    while trip counts (ENTRY = 1). Conservative DFS over the call graph;
    `while` edges multiply by the condition's trip count."""
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps:
            return
        if mult.get(name, 0) >= m:
            return
        mult[name] = max(mult.get(name, 0), m)
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(cond, m)
                visit(body, m * trips)
                continue
            for cm in _CALL_RE.finditer(line):
                visit(cm.group(1), m)

    if entry:
        visit(entry, 1)
    return mult


def parse_collectives(hlo_text: str, world: int) -> List[CollectiveOp]:
    """Collective ops with wire bytes, scaled by while trip counts
    (HloCostAnalysis-style single-visit accounting undercounts scanned
    loops; see analytic.py docstring)."""
    comps = _split_computations(hlo_text)
    if comps:
        mult = _comp_multipliers(comps)
        items = [(name, line) for name, lines in comps.items()
                 for line in lines]
    else:  # fallback: flat text
        mult = {}
        items = [("", line) for line in hlo_text.splitlines()]
    out = []
    for name, line in items:
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rb = _line_result_bytes(line)
        g = _group_size(line, world)
        k = mult.get(name, 1)
        out.append(CollectiveOp(
            op=op, bytes_result=rb, group_size=g,
            wire_bytes=_wire_bytes(op, rb, g) * k,
            line=f"x{k} " + line.strip()[:200]))
    return out


def roofline_report(
    compiled,
    *,
    world: int,
    model_flops_global: float,
    analytic_flops_global: Optional[float] = None,
    analytic_bytes_global: Optional[float] = None,
    steps_hint: str = "",
) -> Dict[str, Any]:
    """Assemble the three-term report from a compiled executable.

    compute/memory terms use the ANALYTIC models when provided (XLA's
    cost analysis undercounts scanned loops — analytic.py docstring);
    the raw cost_analysis numbers are kept in the report for reference.
    The collective term is parsed from the partitioned HLO with while
    trip-count scaling.
    """
    from repro import compat

    ca = compat.cost_analysis(compiled)
    raw_flops_dev = float(ca.get("flops", 0.0))
    raw_bytes_dev = float(ca.get("bytes accessed", 0.0))
    flops_dev = (analytic_flops_global / world
                 if analytic_flops_global else raw_flops_dev)
    bytes_dev = (analytic_bytes_global / world
                 if analytic_bytes_global else raw_bytes_dev)
    colls = parse_collectives(compiled.as_text(), world)
    wire_dev = sum(c.wire_bytes for c in colls)

    by_kind: Dict[str, float] = {}
    for c in colls:
        by_kind[c.op] = by_kind.get(c.op, 0.0) + c.wire_bytes

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = wire_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = model_flops_global / world
    useful = model_flops_dev / flops_dev if flops_dev else 0.0

    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes",
                                          0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        live = (mem["argument_bytes"] + mem["output_bytes"]
                + mem["temp_bytes"] - mem["alias_bytes"])
        mem["live_bytes"] = live
        mem["fits_hbm"] = bool(live <= HBM_BYTES)
        mem["hbm_frac"] = live / HBM_BYTES

    top = sorted(colls, key=lambda c: -c.wire_bytes)[:8]
    return {
        "world": world,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "raw_hlo_flops_per_device": raw_flops_dev,
        "raw_hlo_bytes_per_device": raw_bytes_dev,
        "wire_bytes_per_device": wire_dev,
        "wire_bytes_by_kind": by_kind,
        "terms_seconds": terms,
        "bottleneck": bottleneck,
        "model_flops_global": model_flops_global,
        "useful_flops_ratio": useful,
        "n_collectives": len(colls),
        "top_collectives": [
            {"op": c.op, "wire_bytes": c.wire_bytes, "group": c.group_size}
            for c in top
        ],
        "memory_analysis": mem,
        "note": steps_hint,
    }


def model_flops(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS for the cell: 6ND train, 2ND prefill, 2N·B decode."""
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * active_params * tokens
    # decode: one token per sequence (+ attention over the cache, which
    # is O(cache) and not captured by 2ND — reported separately)
    return 2.0 * active_params * shape.batch
