"""Analytic FLOP / HBM-byte models per (arch x shape) — roofline inputs.

WHY ANALYTIC: XLA's HloCostAnalysis visits each `while` body ONCE
(verified empirically — a scan of 10 matmuls reports 1 matmul of flops),
so any scanned program (all of ours: layer scan, grad-accum scan,
attention chunk scan) is undercounted by its trip counts. The standard
production practice — and what we do here — is an explicit arithmetic
model, the same accounting used for MFU. The compiled artifact still
provides memory_analysis (correct: buffer assignment is static) and the
collective schedule (corrected for trip counts in roofline.py).

Conventions:
* one matmul [m,k]x[k,n] = 2mkn flops
* train multiplier on block compute: fwd(1) + bwd(2) (+1 remat refwd
  under nothing_saveable)
* causal global attention scores/AV count S_ctx/2 average context;
  sliding-window layers count min(window, S) context
* MoE counts top_k routed + shared experts (ideal, no capacity padding)
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import LayerDesc, ModelConfig, ShapeSpec


def _attn_flops(cfg: ModelConfig, t: int, ctx: float) -> float:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.head_dim
    proj = 2 * t * d * (2 * h * hd + 2 * kv * hd)  # q,o + k,v
    scores_av = 2 * 2 * t * ctx * h * hd
    return proj + scores_av


def _mlp_flops(cfg: ModelConfig, t: int, d_ff: int) -> float:
    return 2 * 3 * t * cfg.d_model * d_ff


def _moe_flops(cfg: ModelConfig, t: int) -> float:
    m = cfg.moe
    router = 2 * t * cfg.d_model * m.num_experts
    routed = m.top_k * _mlp_flops(cfg, t, m.d_ff_expert)
    shared = _mlp_flops(cfg, t, m.num_shared * m.d_ff_expert) \
        if m.num_shared else 0.0
    return router + routed + shared


def _ssd_flops(cfg: ModelConfig, t: int) -> float:
    s = cfg.ssm
    d, di, n, h, p = (s.d_model, s.d_inner, s.d_state, s.n_heads,
                      s.head_dim)
    q = s.chunk
    proj = 2 * t * d * (2 * di + 2 * s.n_groups * n + h) \
        + 2 * t * di * d
    conv = 2 * t * (di + 2 * s.n_groups * n) * s.d_conv
    intra = 2 * t * q * h * (n + p)      # scores + att.x
    states = 3 * 2 * t * h * n * p       # states, y_inter, decode-ish
    return proj + conv + intra + states


def _layer_flops(cfg: ModelConfig, desc: LayerDesc, t: int,
                 ctx: float, d_ff_override: int = 0) -> float:
    total = 0.0
    if desc.kind == "attn":
        total += _attn_flops(cfg, t, ctx)
    else:
        total += _ssd_flops(cfg, t)
    if desc.ff == "dense":
        total += _mlp_flops(cfg, t, d_ff_override or cfg.d_ff)
    elif desc.ff == "moe":
        total += _moe_flops(cfg, t)
    return total


def _ctx_for(cfg: ModelConfig, desc: LayerDesc, shape: ShapeSpec) -> float:
    s = shape.seq
    if shape.kind == "decode":
        full = float(s)
        # baseline decode scans the full (masked) cache even for local
        # layers; the ring cache bounds executed work to the window
        if desc.kind == "attn" and desc.attn_type == "local" \
                and cfg.local_ring_cache:
            return min(float(cfg.local_window), full)
        return full
    full = s / 2.0  # causal average
    if desc.kind == "attn" and desc.attn_type == "local":
        return min(float(cfg.local_window), full)
    return full


def flops_model(cfg: ModelConfig, shape: ShapeSpec, *,
                grad_accum: int = 1, remat: bool = True
                ) -> Dict[str, float]:
    b, s = shape.batch, shape.seq
    t = b * (1 if shape.kind == "decode" else s)

    # blocks
    block = 0.0
    for desc in cfg.pattern:
        block += _layer_flops(cfg, desc, t, _ctx_for(cfg, desc, shape))
    block *= cfg.num_blocks
    if cfg.dense_first_layer:
        block += _layer_flops(
            cfg, LayerDesc(kind="attn", ff="dense"), t,
            _ctx_for(cfg, LayerDesc(), shape), cfg.dense_first_d_ff)
    if cfg.is_encdec:
        tf = b * cfg.encoder_frames
        enc = cfg.encoder_layers * (
            _attn_flops(cfg, tf, cfg.encoder_frames)
            + _mlp_flops(cfg, tf, cfg.d_ff))
        cross = cfg.num_layers * _attn_flops(cfg, t, cfg.encoder_frames)
        block += enc + cross

    logits = 2 * t * cfg.d_model * cfg.vocab_size

    if shape.kind == "train":
        mult = 3.0 + (1.0 if remat else 0.0)
        total = block * mult + logits * 3.0
    else:
        total = block + logits
    return {
        "flops_global": total,
        "flops_block_fwd": block,
        "flops_logits_fwd": logits,
    }


# ---------------------------------------------------------------------------
# HBM bytes (per step, global; divide by chips for per-device)
# ---------------------------------------------------------------------------

def bytes_model(cfg: ModelConfig, shape: ShapeSpec, *,
                param_count: int, grad_accum: int = 1,
                opt_bytes_per_param: int = 8, remat: bool = True
                ) -> Dict[str, float]:
    b, s = shape.batch, shape.seq
    pbytes = 2.0 * param_count  # bf16 weights
    d = cfg.d_model

    if shape.kind == "train":
        a = max(1, grad_accum)
        micro_t = b * s / a
        # weights: read per microbatch fwd + bwd (+ remat refwd)
        w_traffic = pbytes * a * (2.0 + (1.0 if remat else 0.0))
        # grads: f32 accumulate read+write per microbatch + opt read
        g_traffic = 4.0 * param_count * (2.0 * a + 1.0)
        # optimizer: m, v read+write, params read+write (f32 math)
        o_traffic = (2.0 * opt_bytes_per_param + 2 * 4.0) * param_count
        # activations: saved carry per block (bf16) written + read
        act = 2.0 * cfg.num_blocks * micro_t * d * 2.0 * a
        # logits fwd+bwd in f32
        logit = 2.0 * b * s * cfg.vocab_size * 2.0
        total = w_traffic + g_traffic + o_traffic + act + logit
    elif shape.kind == "prefill":
        t = b * s
        attn_layers = sum(1 for dd in cfg.pattern if dd.kind == "attn") \
            * cfg.num_blocks + (1 if cfg.dense_first_layer else 0)
        kvb = 2.0 * attn_layers * t * cfg.num_kv_heads \
            * cfg.head_dim * 2.0
        act = 2.0 * cfg.num_blocks * t * d * 2.0
        total = pbytes + kvb + act + 2.0 * t * cfg.vocab_size
    else:  # decode: weights + cache read dominate. A local layer only
        # reads its window IF the ring cache is enabled; the baseline
        # full-capacity cache is scanned (masked) in its entirety.
        cache = 0.0
        for dd in cfg.pattern:
            if dd.kind != "attn":
                continue
            ctx = min(cfg.local_window, s) \
                if (dd.attn_type == "local" and cfg.local_ring_cache) \
                else s
            cache += (2.0 * cfg.num_blocks * b * ctx
                      * cfg.num_kv_heads * cfg.head_dim * 2.0)
        if cfg.dense_first_layer:
            cache += 2.0 * b * s * cfg.num_kv_heads * cfg.head_dim * 2.0
        if cfg.is_encdec:
            cache += 2.0 * cfg.num_layers * b * cfg.encoder_frames \
                * cfg.num_kv_heads * cfg.head_dim * 2.0
        total = pbytes + cache + 2.0 * b * cfg.vocab_size
    return {"bytes_global": total}
