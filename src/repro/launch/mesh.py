"""Production mesh construction (DESIGN.md §5.4).

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods x
256 = 512 chips (pod, data, model) — the 'pod' axis rides DCI-class
links, which is why gradient compression (train/compress.py) targets it
and why the roofline separates its bytes.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (4, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
