"""Training driver: end-to-end fit() on whatever mesh is available.

Used by examples/train_embedder.py (CPU, reduced config) and, unchanged,
by a real TPU launch — the mesh/sharding/checkpoint plumbing is the
production path. The loop composes: stateless token pipeline ->
train_step (jit, sharded) -> Supervisor (checkpoint/restart/stragglers).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax

from repro.configs.base import ModelConfig
from repro.data import tokens as tokens_mod
from repro.launch import sharding as shard_lib
from repro.models import model as model_mod
from repro.models.params import initialize
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import Checkpointer
from repro.train.fault import FaultInjector, Supervisor
from repro.train.train_step import build_train_step


def fit(
    cfg: ModelConfig,
    *,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    seed: int = 0,
    opt_cfg: Optional[opt_mod.OptConfig] = None,
    mesh=None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    grad_accum: int = 1,
    resume: bool = True,
    injector: Optional[FaultInjector] = None,
    log_every: int = 10,
) -> Dict[str, Any]:
    opt_cfg = opt_cfg or opt_mod.OptConfig(
        lr=1e-3, warmup_steps=min(20, steps // 5 + 1), total_steps=steps)
    key = jax.random.PRNGKey(seed)
    specs = model_mod.model_specs(cfg)
    params = initialize(specs, key)
    opt_state = opt_mod.init(opt_cfg, params)

    step_fn = build_train_step(cfg, opt_cfg, grad_accum=grad_accum)
    if mesh is not None:
        rules = shard_lib.mesh_rules(mesh)
        from repro.models import params as params_mod

        p_sh = params_mod.shardings(specs, rules, mesh)
        o_sh = shard_lib.opt_shardings(cfg, opt_cfg, mesh, rules)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    def make_batch(step: int):
        b = tokens_mod.batch_at_step(seed, step, batch, seq,
                                     cfg.vocab_size)
        if cfg.is_encdec:
            k = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
            b["frames"] = jax.random.normal(
                k, (batch, cfg.encoder_frames, cfg.d_model),
                cfg.compute_dtype)
        return b

    start_step = 0
    ckpt = None
    if ckpt_dir:
        ckpt = Checkpointer(ckpt_dir)
        latest = ckpt.latest_step() if resume else None
        if latest is not None:
            _, state, _ = ckpt.restore(
                {"params": params, "opt_state": opt_state}, latest)
            params, opt_state = state["params"], state["opt_state"]
            start_step = latest
    if ckpt is None:
        ckpt = Checkpointer(ckpt_dir or
                            os.path.join("/tmp", f"hydra_ckpt_{seed}"))

    sup = Supervisor(
        train_step=step_fn, make_batch=make_batch, ckpt=ckpt,
        ckpt_every=ckpt_every, injector=injector)
    out = sup.run(params, opt_state, start_step, steps - start_step,
                  log_every=log_every)
    return out
