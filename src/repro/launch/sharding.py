"""Logical-axis -> mesh sharding for every lowered entry point.

One rule set (models/params.DEFAULT_RULES) serves all 10 architectures;
the resolver degrades gracefully (divisibility, axis reuse, missing mesh
axes), which is what makes e.g. GQA kv_heads=8 on a 16-way model axis
shard head_dim instead (DESIGN.md §5.4).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model as model_mod
from repro.models import params as params_mod
from repro.models.params import DEFAULT_RULES
from repro.train import optimizer as opt_mod


def mesh_rules(mesh: Mesh, overrides: Optional[Dict[str, Any]] = None):
    """DEFAULT_RULES filtered to this mesh's axes (+ overrides)."""
    names = set(mesh.axis_names)
    rules = {}
    src = dict(DEFAULT_RULES)
    if overrides:
        src.update(overrides)
    for k, v in src.items():
        if v is None:
            rules[k] = None
        elif isinstance(v, str):
            rules[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            rules[k] = kept if kept else None
    return rules


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules=None):
    rules = rules or mesh_rules(mesh)
    specs = model_mod.model_specs(cfg)
    return params_mod.shardings(specs, rules, mesh)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, rules=None):
    rules = rules or mesh_rules(mesh)
    specs = model_mod.model_specs(cfg)
    return params_mod.partition_specs(specs, rules, mesh)


def input_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    rules=None):
    rules = rules or mesh_rules(mesh)
    specs = model_mod.input_specs(cfg, shape)
    return params_mod.shardings(specs, rules, mesh)


def abstract_params(cfg: ModelConfig):
    return params_mod.abstract(model_mod.model_specs(cfg))


def abstract_inputs(cfg: ModelConfig, shape: ShapeSpec):
    return params_mod.abstract(model_mod.input_specs(cfg, shape))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: opt_mod.OptConfig):
    """ShapeDtypeStruct pytree of optimizer state (no allocation)."""
    import jax.numpy as jnp

    p = abstract_params(cfg)
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, opt_cfg.state_dtype), p)
    mom2 = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, opt_cfg.state_dtype), p)
    return opt_mod.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=mom, nu=mom2)


def opt_shardings(cfg: ModelConfig, opt_cfg, mesh: Mesh, rules=None):
    rules = rules or mesh_rules(mesh)
    psh = param_shardings(cfg, mesh, rules)
    return opt_mod.OptState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree_util.tree_map(lambda s: s, psh),
        nu=jax.tree_util.tree_map(lambda s: s, psh),
    )
