import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# XLA device-count override must precede any jax import (see dryrun.py).

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.obs import now  # noqa: E402
from repro.core.histogram import DistanceHistogram  # noqa: E402
from repro.core.index import FrozenIndex  # noqa: E402
from repro.core.search import SearchResult, search_impl  # noqa: E402
from repro.launch import roofline as roof  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

"""Production dry-run of the paper's own technique: a billion-series
DistributedSearchEngine query lowered + compiled on the 256/512-chip
meshes (this is the cell the §Perf loop hillclimbs as "most
representative of the paper").

Configuration mirrors the paper's disk-scale setting, scaled to pod HBM:
per-shard 2M series x 256 f32 (2 GB/chip), leaf_cap 512, batched 256
queries, k=100, ng(nprobe) visits — 512 chips hold 1.02B series, i.e.
the Deep1B/Sift1B regime the paper calls the largest public datasets.
"""


def abstract_index(mesh, axes, n_per_shard: int, series_len: int,
                   leaf_cap: int, summary: str = "eapca"):
    shards = 1
    for a in axes:
        shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    leaves = n_per_shard // leaf_cap
    dims = {"paa": 16, "eapca": 16, "dft": 16}[summary]
    spec0 = P(axes if len(axes) > 1 else axes[0])

    def sds(shape, spec):
        return jax.ShapeDtypeStruct(
            shape, jnp.float32, sharding=NamedSharding(mesh, spec))

    def sdsi(shape, spec):
        return jax.ShapeDtypeStruct(
            shape, jnp.int32, sharding=NamedSharding(mesh, spec))

    idx = FrozenIndex(
        box_lo=sds((shards, leaves, dims), spec0),
        box_hi=sds((shards, leaves, dims), spec0),
        weights=sds((dims,), P()),
        offsets=sdsi((shards, leaves + 1), spec0),
        data=sds((shards, n_per_shard, series_len), spec0),
        ids=sdsi((shards, n_per_shard), spec0),
        hist=DistanceHistogram(edges=sds((513,), P()),
                               cdf=sds((513,), P())),
        kind="dstree", summary=summary, n_summary=8,
        max_leaf=leaf_cap, n_total=n_per_shard * shards,
        series_len=series_len,
        # cached |x|^2 (PR 3): the refinement loop gathers norms
        # instead of re-reducing gathered rows each iteration
        row_norms=sds((shards, n_per_shard), spec0),
    )
    return idx, shards, leaves


def lower_search(mesh, *, n_per_shard=2_000_000, series_len=256,
                 leaf_cap=512, batch=256, k=100, nprobe=128,
                 visit_batch=8, data_bf16=False, coop=False):
    # pure search has no tensor dimension to 'model'-parallelize: every
    # chip owns a DB shard — 256 shards x 2M = 512M series single-pod,
    # 512 x 2M = 1.02B multi-pod (the paper's Deep1B/Sift1B scale)
    axes = tuple(mesh.axis_names)
    idx, shards, leaves = abstract_index(
        mesh, axes, n_per_shard, series_len, leaf_cap)
    if data_bf16:
        import dataclasses as _dc
        import jax.numpy as _jnp
        old = idx.data
        idx = _dc.replace(idx, data=jax.ShapeDtypeStruct(
            old.shape, _jnp.bfloat16, sharding=old.sharding))
    q_sds = jax.ShapeDtypeStruct(
        (batch, series_len), jnp.float32,
        sharding=NamedSharding(mesh, P()))
    spec0 = P(axes if len(axes) > 1 else axes[0])
    in_specs = (
        FrozenIndex(
            box_lo=spec0, box_hi=spec0, offsets=spec0, data=spec0,
            ids=spec0, weights=P(),
            hist=DistanceHistogram(edges=P(), cdf=P()),
            kind=idx.kind, summary=idx.summary, n_summary=idx.n_summary,
            max_leaf=idx.max_leaf, n_total=idx.n_total,
            series_len=idx.series_len, row_norms=spec0,
        ),
        P(),
    )

    def local(idx_local, q):
        sq = jax.tree_util.tree_map(
            lambda a: a[0], (idx_local.box_lo, idx_local.box_hi,
                             idx_local.offsets, idx_local.data,
                             idx_local.ids, idx_local.row_norms))
        lidx = dataclasses.replace(
            idx_local, box_lo=sq[0], box_hi=sq[1], offsets=sq[2],
            data=sq[3], ids=sq[4], row_norms=sq[5])
        # repro: allow[jax-while-shard-map] compile-only roofline dry run: the jitted executable is lowered and cost-analyzed, never executed, so the 0.4.37 runtime miscompile cannot produce wrong numbers here
        res = search_impl(lidx, q, k, nprobe=nprobe,
                          visit_batch=visit_batch,
                          share_gathers=coop)
        all_d = res.dists
        all_i = res.ids
        for ax in axes:
            all_d = jax.lax.all_gather(all_d, ax, tiled=False)
            all_i = jax.lax.all_gather(all_i, ax, tiled=False)
        all_d = all_d.reshape(-1, batch, k).transpose(1, 0, 2) \
            .reshape(batch, -1)
        all_i = all_i.reshape(-1, batch, k).transpose(1, 0, 2) \
            .reshape(batch, -1)
        sd, si = jax.lax.sort((all_d, all_i), num_keys=1)
        return SearchResult(sd[:, :k], si[:, :k],
                            jax.lax.psum(res.leaves_visited, axes),
                            jax.lax.psum(res.rows_scanned, axes),
                            jax.lax.psum(res.lb_computed, axes))

    fn = compat.shard_map(local, mesh=mesh, in_specs=in_specs,
                          out_specs=SearchResult(P(), P(), P(), P(), P()),
                          check=False)
    t0 = now()
    lowered = jax.jit(fn).lower(idx, q_sds)
    compiled = lowered.compile()
    t_compile = now() - t0

    world = mesh.devices.size
    # analytic terms (per shard, data-dependent loop bounded by nprobe)
    visited_rows = nprobe * leaf_cap
    # cooperative batching: measured 25% fewer gathers at exact, and
    # every gathered row is scored by all B lanes (one MXU matmul)
    gather_eff = 0.75 if coop else 1.0
    score_mult = batch if coop else 1.0
    dbytes = 2.0 if data_bf16 else 4.0
    flops_shard = (
        batch * leaves * idx.n_summary * 4.0          # box lb pass
        + gather_eff * batch * visited_rows * series_len * 2.0
        * score_mult                                  # refinement L2
    )
    bytes_shard = (
        leaves * idx.n_summary * 2 * 4.0              # boxes
        + gather_eff * batch * visited_rows * series_len * dbytes
    )
    chips_per_shard = world / (idx.box_lo.shape[0])
    rep = roof.roofline_report(
        compiled, world=world,
        model_flops_global=flops_shard * idx.box_lo.shape[0],
        analytic_flops_global=flops_shard * idx.box_lo.shape[0],
        analytic_bytes_global=bytes_shard * idx.box_lo.shape[0],
        steps_hint=f"search nprobe={nprobe} vb={visit_batch} "
                   f"chips/shard={chips_per_shard:.0f}",
    )
    rep.update({
        "arch": "search-engine", "shape": f"scan_n{n_per_shard}",
        "status": "ok", "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "compile_seconds": round(t_compile, 1),
        "n_total_series": idx.n_total,
    })
    print(compiled.memory_analysis())
    ca = compat.cost_analysis(compiled)
    print({kk: ca[kk] for kk in ("flops", "bytes accessed") if kk in ca})
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--n-per-shard", type=int, default=2_000_000)
    ap.add_argument("--nprobe", type=int, default=128)
    ap.add_argument("--bf16-data", action="store_true")
    ap.add_argument("--coop", action="store_true")
    ap.add_argument("--tag", default="scan")
    args = ap.parse_args()
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))
    for name, mesh in meshes:
        outdir = os.path.join(args.out, name)
        os.makedirs(outdir, exist_ok=True)
        print(f"=== {name} :: search-engine ===", flush=True)
        with mesh:
            rep = lower_search(mesh, n_per_shard=args.n_per_shard,
                               nprobe=args.nprobe,
                               data_bf16=args.bf16_data, coop=args.coop)
        with open(os.path.join(outdir, f"search-engine__{args.tag}.json"),
                  "w") as f:
            json.dump(rep, f, indent=2, default=str)
        t = rep["terms_seconds"]
        print(f"ok compile={rep['compile_seconds']}s "
              f"compute={t['compute']:.4f}s memory={t['memory']:.4f}s "
              f"coll={t['collective']:.4f}s "
              f"bottleneck={rep['bottleneck']} "
              f"series={rep['n_total_series']:,}", flush=True)


if __name__ == "__main__":
    main()
