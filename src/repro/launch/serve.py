"""Serving driver: bucketed batch decode + retrieval-augmented answers.

Drives serve/batching.Scheduler over serve/serve_step.generate, with a
retrieval engine as a first-class feature: each request may carry a
``series`` query in the engine's series space, and the scheduler's
retrieval front partitions every drained batch by its deadline-mapped
guarantee (epsilon -> delta-epsilon -> ng(nprobe) graceful
degradation, serve/batching.guarantee_for_deadline) and issues one
``engine.query`` per group. The engine decides residency per shard —
HBM-resident shard_map search or the host-driven out-of-core loop
over spilled stores (core/engine.DistributedEngine.query) — so the
same serving front covers collections far larger than device memory.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.batching import Request, Scheduler, guarantee_for_deadline
from repro.serve.serve_step import generate


def serve_requests(
    params,
    cfg: ModelConfig,
    requests: List[Request],
    *,
    engine=None,
    retrieval_k: int = 5,
    max_batch: int = 8,
    guarantee_kw: Optional[dict] = None,
) -> Dict[int, Dict[str, Any]]:
    """Serve a request list to completion. With ``engine`` set, every
    request carrying a ``series`` query gets a ``retrieval`` entry
    ({ids, dists, kind}) answered under the guarantee its deadline
    affords; ``guarantee_kw`` tunes the deadline->guarantee mapping
    (budgets, degraded tiers — see guarantee_for_deadline)."""
    sched = Scheduler(max_batch=max_batch)
    for r in requests:
        sched.submit(r)
    gkw = dict(guarantee_kw or {})
    results: Dict[int, Dict[str, Any]] = {}
    while True:
        nb = sched.next_batch()
        if nb is None:
            break
        bucket, reqs = nb
        prompts = jnp.asarray(sched.pad_prompts(bucket, reqs))
        n_new = max(r.max_new_tokens for r in reqs)
        t0 = time.perf_counter()
        toks, aux = generate(params, cfg, prompts, n_new)
        retrieved: Dict[int, Dict[str, Any]] = {}
        if engine is not None:
            # the retrieval front: one engine.query per deadline-
            # mapped guarantee group, overlapping nothing — retrieval
            # latency is part of the request's budget
            retrieved = sched.run_retrieval(
                engine, reqs, retrieval_k, **gkw)
        latency = (time.perf_counter() - t0) * 1e3
        for i, r in enumerate(reqs):
            entry: Dict[str, Any] = {
                "tokens": np.asarray(toks[i, : r.max_new_tokens]),
                "latency_ms": latency,
                "guarantee": guarantee_for_deadline(
                    r.deadline_ms, **gkw).kind,
            }
            if r.uid in retrieved:
                hit = retrieved[r.uid]
                entry["retrieval"] = {
                    "ids": hit["ids"], "dists": hit["dists"],
                    "kind": hit["kind"],
                }
            results[r.uid] = entry
    return results
