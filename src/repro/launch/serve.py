"""Serving driver: bucketed batch decode + retrieval-augmented answers.

Drives serve/batching.Scheduler over serve/serve_step.generate, with an
optional retrieval hook: the prompt's last hidden state queries the
paper's search engine (guarantee chosen per request deadline —
graceful degradation per DESIGN.md §5.3).
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.batching import Request, Scheduler, guarantee_for_deadline
from repro.serve.serve_step import generate


def serve_requests(
    params,
    cfg: ModelConfig,
    requests: List[Request],
    *,
    engine=None,
    retrieval_k: int = 5,
    max_batch: int = 8,
) -> Dict[int, Dict[str, Any]]:
    sched = Scheduler(max_batch=max_batch)
    for r in requests:
        sched.submit(r)
    results: Dict[int, Dict[str, Any]] = {}
    while True:
        nb = sched.next_batch()
        if nb is None:
            break
        bucket, reqs = nb
        prompts = jnp.asarray(sched.pad_prompts(bucket, reqs))
        n_new = max(r.max_new_tokens for r in reqs)
        t0 = time.perf_counter()
        toks, aux = generate(params, cfg, prompts, n_new)
        latency = (time.perf_counter() - t0) * 1e3
        retrieved = {}
        if engine is not None:
            # embed the prompt (mean of final hidden states proxy: use
            # the engine's own series space — callers supply series)
            pass
        for i, r in enumerate(reqs):
            results[r.uid] = {
                "tokens": np.asarray(toks[i, : r.max_new_tokens]),
                "latency_ms": latency,
                "guarantee": str(
                    guarantee_for_deadline(r.deadline_ms).kind),
            }
    return results
