"""Serving driver: bucketed batch decode + retrieval-augmented answers.

Drives serve/batching.Scheduler over serve/serve_step.generate, with a
retrieval engine as a first-class feature: each request may carry a
``series`` query in the engine's series space, and the scheduler's
retrieval front partitions every drained batch by its deadline-mapped
guarantee (epsilon -> delta-epsilon -> ng(nprobe) graceful
degradation, serve/batching.guarantee_for_deadline) and issues one
``engine.query`` per group. The engine decides residency per shard —
HBM-resident shard_map search or the host-driven out-of-core loop
over spilled stores (core/engine.DistributedEngine.query) — so the
same serving front covers collections far larger than device memory.

Latency attribution (PR 6): every request's reported ``latency_ms``
is the sum of ITS OWN components on the one shared monotonic clock
(``obs.now`` — Request.submitted_at is stamped on the same clock):

  queue_wait_ms   submit -> its batch starts draining
  generate_ms     the decode step of its batch (shared by the batch)
  retrieval_ms    its OWN guarantee group's engine time (a request in
                  the cheap ng group is no longer charged for the
                  expensive epsilon group's retrieval, which the old
                  whole-batch timer did)

Per-request components land in the metrics registry as
``serve.queue_wait_ms`` / ``serve.generate_ms`` /
``serve.latency_ms{kind=...}`` histograms plus
``serve.deadline.{hit,miss}{kind=...}`` counters, and each drained
batch is a ``serve.batch`` span when tracing is enabled
(docs/OBSERVABILITY.md).

Two fronts (docs/SERVING.md): :func:`serve_requests` is the STATIC
barrier loop — drain a batch, decode it, retrieve for it, repeat; a
request's retrieval waits for its whole batch round.
:func:`serve_requests_continuous` routes retrieval through the
continuous-batching :class:`repro.serve.loop.ServeFront` instead:
retrieval is submitted at REQUEST-submit time into per-guarantee
lanes that refill as engine calls complete, overlapping decode, with
admission control and shedding. The static loop stays as the bench
baseline (benchmarks/bench_serve_load.py measures both sides of the
latency-vs-load curve).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.serve.batching import Request, Scheduler, guarantee_for_deadline
from repro.serve.loop import Rejected, ServeFront
from repro.serve.serve_step import generate


def serve_requests(
    params,
    cfg: ModelConfig,
    requests: List[Request],
    *,
    engine=None,
    retrieval_k: int = 5,
    max_batch: int = 8,
    guarantee_kw: Optional[dict] = None,
) -> Dict[int, Dict[str, Any]]:
    """Serve a request list to completion. With ``engine`` set, every
    request carrying a ``series`` query gets a ``retrieval`` entry
    ({ids, dists, kind}) answered under the guarantee its deadline
    affords; ``guarantee_kw`` tunes the deadline->guarantee mapping
    (budgets, degraded tiers — see guarantee_for_deadline). Each
    result entry carries the per-request latency breakdown
    (queue_wait_ms / generate_ms / retrieval_ms / latency_ms) and a
    ``deadline_hit`` flag when the request had a deadline."""
    sched = Scheduler(max_batch=max_batch)
    for r in requests:
        sched.submit(r)
    gkw = dict(guarantee_kw or {})
    results: Dict[int, Dict[str, Any]] = {}
    while True:
        nb = sched.next_batch()
        if nb is None:
            break
        bucket, reqs = nb
        with obs.span("serve.batch", bucket=bucket, requests=len(reqs)):
            t_drain = obs.now()
            prompts = jnp.asarray(sched.pad_prompts(bucket, reqs))
            n_new = max(r.max_new_tokens for r in reqs)
            with obs.span("serve.generate", tokens=n_new):
                t0 = obs.now()
                toks, aux = generate(params, cfg, prompts, n_new)
                toks = jax.block_until_ready(toks)
                generate_ms = (obs.now() - t0) * 1e3
            retrieved: Dict[int, Dict[str, Any]] = {}
            if engine is not None:
                # the retrieval front: one engine.query per deadline-
                # mapped guarantee group, overlapping nothing —
                # retrieval latency is part of the request's budget
                retrieved = sched.run_retrieval(
                    engine, reqs, retrieval_k, **gkw)
            for i, r in enumerate(reqs):
                kind = guarantee_for_deadline(r.deadline_ms, **gkw).kind
                queue_wait_ms = max(
                    (t_drain - r.submitted_at) * 1e3, 0.0)
                retrieval_ms = retrieved.get(
                    r.uid, {}).get("retrieval_ms", 0.0)
                latency_ms = queue_wait_ms + generate_ms + retrieval_ms
                entry: Dict[str, Any] = {
                    "tokens": np.asarray(toks[i, : r.max_new_tokens]),
                    "latency_ms": latency_ms,
                    "queue_wait_ms": queue_wait_ms,
                    "generate_ms": generate_ms,
                    "retrieval_ms": retrieval_ms,
                    "guarantee": kind,
                }
                reg = obs.REGISTRY
                reg.histogram("serve.queue_wait_ms").record(
                    queue_wait_ms)
                reg.histogram("serve.generate_ms").record(generate_ms)
                reg.histogram("serve.latency_ms", kind=kind).record(
                    latency_ms)
                if r.deadline_ms is not None:
                    hit = latency_ms <= r.deadline_ms
                    entry["deadline_hit"] = bool(hit)
                    reg.counter(
                        "serve.deadline.hit" if hit
                        else "serve.deadline.miss", kind=kind).inc()
                if r.uid in retrieved:
                    hit_r = retrieved[r.uid]
                    entry["retrieval"] = {
                        "ids": hit_r["ids"], "dists": hit_r["dists"],
                        "kind": hit_r["kind"],
                        "stats": hit_r.get("stats"),
                    }
                    if hit_r.get("degraded"):
                        # shard(s) lost past retries/replicas: the
                        # answer is honest delta-epsilon, not the
                        # requested tier (docs/FAULT.md)
                        entry["retrieval"]["degraded"] = True
                        entry["retrieval"]["requested_kind"] = \
                            hit_r["requested_kind"]
                        entry["retrieval"]["effective_delta"] = \
                            hit_r["effective_delta"]
                        entry["retrieval"]["shards_lost"] = \
                            hit_r["shards_lost"]
                results[r.uid] = entry
    return results


def serve_requests_continuous(
    params,
    cfg: ModelConfig,
    requests: List[Request],
    *,
    engine=None,
    retrieval_k: int = 5,
    max_batch: int = 8,
    guarantee_kw: Optional[dict] = None,
    admission=None,
) -> Dict[int, Dict[str, Any]]:
    """Serve a request list with retrieval on the continuous front.

    Retrieval is submitted to a :class:`ServeFront` the moment a
    request enters the system, so engine calls overlap the decode
    batches instead of serializing after them (the static loop's
    barrier). Each request's ``latency_ms`` is the LATER of its decode
    completion and its retrieval completion minus its submit stamp —
    the component breakdown (queue_wait / generate / retrieval) is
    unchanged, but retrieval time the decode path already covered
    costs nothing extra. A request rejected by admission control
    (``admission`` caps in-system retrieval depth) still decodes;
    its entry carries ``retrieval_rejected`` with the reason. The
    front remaps guarantees from the REMAINING deadline budget at
    drain time and degrades tiers under shedding — the ``retrieval``
    entry's ``kind`` is the tier actually honored."""
    sched = Scheduler(max_batch=max_batch)
    gkw = dict(guarantee_kw or {})
    tickets: Dict[int, Any] = {}
    rejected: Dict[int, str] = {}
    front = None
    if engine is not None:
        front = ServeFront(engine, retrieval_k, max_batch=max_batch,
                           admission=admission,
                           guarantee_kw=gkw).start()
    try:
        for r in requests:
            sched.submit(r)
            if front is not None and r.series is not None:
                try:
                    tickets[r.uid] = front.submit(r)
                except Rejected as e:
                    rejected[r.uid] = e.reason
        results: Dict[int, Dict[str, Any]] = {}
        decode_done: Dict[int, float] = {}
        while True:
            nb = sched.next_batch()
            if nb is None:
                break
            bucket, reqs = nb
            with obs.span("serve.batch", bucket=bucket,
                          requests=len(reqs)):
                t_drain = obs.now()
                prompts = jnp.asarray(sched.pad_prompts(bucket, reqs))
                n_new = max(r.max_new_tokens for r in reqs)
                with obs.span("serve.generate", tokens=n_new):
                    t0 = obs.now()
                    toks, aux = generate(params, cfg, prompts, n_new)
                    toks = jax.block_until_ready(toks)
                    generate_ms = (obs.now() - t0) * 1e3
                for i, r in enumerate(reqs):
                    queue_wait_ms = max(
                        (t_drain - r.submitted_at) * 1e3, 0.0)
                    results[r.uid] = {
                        "tokens": np.asarray(
                            toks[i, : r.max_new_tokens]),
                        "queue_wait_ms": queue_wait_ms,
                        "generate_ms": generate_ms,
                        "retrieval_ms": 0.0,
                    }
                    decode_done[r.uid] = obs.now()
        if front is not None:
            front.stop(drain=True)
            front = None
        reg = obs.REGISTRY
        for r in requests:
            entry = results[r.uid]
            done = decode_done[r.uid]
            kind = guarantee_for_deadline(r.deadline_ms, **gkw).kind
            if r.uid in tickets:
                hit_r = tickets[r.uid].result()
                if "error" in hit_r:
                    entry["retrieval_error"] = hit_r["error"]
                else:
                    entry["retrieval_ms"] = hit_r["retrieval_ms"]
                    done = max(done, hit_r["done_at"])
                    kind = hit_r["kind"]
                    entry["retrieval"] = {
                        k: hit_r[k] for k in
                        ("ids", "dists", "kind", "nominal_kind",
                         "stats")}
                    for extra in ("shed", "degraded", "requested_kind",
                                  "effective_delta", "shards_lost"):
                        if extra in hit_r:
                            entry["retrieval"][extra] = hit_r[extra]
            elif r.uid in rejected:
                entry["retrieval_rejected"] = rejected[r.uid]
            latency_ms = max((done - r.submitted_at) * 1e3, 0.0)
            entry["latency_ms"] = latency_ms
            entry["guarantee"] = kind
            reg.histogram("serve.queue_wait_ms").record(
                entry["queue_wait_ms"])
            reg.histogram("serve.generate_ms").record(
                entry["generate_ms"])
            reg.histogram("serve.latency_ms", kind=kind).record(
                latency_ms)
            if r.deadline_ms is not None:
                hit = latency_ms <= r.deadline_ms
                entry["deadline_hit"] = bool(hit)
                reg.counter(
                    "serve.deadline.hit" if hit
                    else "serve.deadline.miss", kind=kind).inc()
        return results
    finally:
        if front is not None:
            front.stop(drain=False)
