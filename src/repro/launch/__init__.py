# Launch layer: production meshes, sharding rules, dry-run + drivers.
# NOTE: dryrun.py sets XLA_FLAGS at import — import it only as an entry
# point (python -m repro.launch.dryrun), never from library code.
