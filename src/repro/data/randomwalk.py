"""The paper's synthetic dataset generator (§4.1 Datasets).

Random-walk series: cumulative sum of N(0,1) steps — the standard model
of financial series used throughout the data-series literature [56, 33,
165]. Generation is *stateless*: series i of a dataset is a pure function
of (seed, i), so shards can generate their rows independently on any host
(no broadcast of raw data at pod scale) and restarts regenerate
identically — this is the data-side half of fault tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


BLOCK = 1024  # fixed addressing granularity — never change


def generate(
    seed: int, n_series: int, series_len: int, *, znorm: bool = True,
    start: int = 0,
) -> np.ndarray:
    """Rows [start, start+n_series) of dataset `seed` (numpy, host).

    Rows are generated in fixed BLOCK-aligned chunks, each seeded by
    (seed, block_id, series_len), so any row range regenerates
    identically regardless of how the request is sliced across hosts.
    """
    if n_series == 0:
        return np.zeros((0, series_len), np.float32)
    b0 = start // BLOCK
    b1 = (start + n_series - 1) // BLOCK
    chunks = []
    for b in range(b0, b1 + 1):
        rng = np.random.default_rng((seed, b, series_len))
        chunks.append(rng.normal(size=(BLOCK, series_len))
                      .astype(np.float32))
    allb = np.concatenate(chunks, axis=0)
    ofs = start - b0 * BLOCK
    out = np.cumsum(allb[ofs:ofs + n_series], axis=1)
    if znorm:
        mu = out.mean(axis=1, keepdims=True)
        sd = out.std(axis=1, keepdims=True) + 1e-9
        out = (out - mu) / sd
    return out


def generate_device(
    key: jax.Array, n_series: int, series_len: int, *, znorm: bool = True,
) -> jax.Array:
    """Device-side generation (for tests / on-device pipelines)."""
    steps = jax.random.normal(key, (n_series, series_len), jnp.float32)
    walk = jnp.cumsum(steps, axis=1)
    if znorm:
        mu = walk.mean(axis=1, keepdims=True)
        sd = walk.std(axis=1, keepdims=True) + 1e-9
        walk = (walk - mu) / sd
    return walk
