from . import pipeline, queries, randomwalk, tokens

__all__ = ["pipeline", "queries", "randomwalk", "tokens"]
