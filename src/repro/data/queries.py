"""Query workload generation (paper §4.1 Queries).

Following Zoumpatianos et al. [164] as the paper does: queries are data
series drawn from the collection with progressively larger additive
Gaussian noise, producing controlled difficulty levels. Synthetic
workloads use the same random-walk generator with a different seed.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def noisy_queries(
    data: np.ndarray,
    n_queries: int,
    noise_levels: Sequence[float] = (0.0, 0.01, 0.05, 0.1, 0.25),
    seed: int = 7,
) -> np.ndarray:
    """[n_queries, n] — difficulty cycles through noise_levels."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(data.shape[0], n_queries, replace=False)
    q = data[idx].copy()
    scale = data.std()
    for i in range(n_queries):
        lvl = noise_levels[i % len(noise_levels)]
        q[i] += rng.normal(0, lvl * scale, data.shape[1]).astype(
            np.float32)
    return q.astype(np.float32)
