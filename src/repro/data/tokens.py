"""Synthetic token pipeline for LM training (stateless, skip-ahead).

Batches are a pure function of (seed, step): restart-safe with no replay
drift and shardable by slicing the global batch — each data-parallel
group materializes only its rows. Tokens follow a two-state Markov
mixture over a Zipf-ish unigram so the LM loss has learnable structure
(uniform tokens would leave nothing to fit but the bias).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _zipf_logits(vocab: int, alpha: float = 1.2) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def batch_at_step(
    seed: int, step: int, batch: int, seq: int, vocab: int,
    *, row_start: int = 0, row_count: int = -1,
) -> Dict[str, jax.Array]:
    """Global batch for `step`, optionally only rows
    [row_start, row_start+row_count)."""
    rows = batch if row_count < 0 else row_count
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    key = jax.random.fold_in(key, row_start)
    logits = _zipf_logits(vocab)
    # sample seq+1 then shift -> (tokens, labels)
    toks = jax.random.categorical(
        key, jnp.broadcast_to(logits, (rows, seq + 1, vocab)))
    # inject copy structure: every other position repeats with offset 1
    k2 = jax.random.fold_in(key, 1)
    rep = jax.random.bernoulli(k2, 0.5, (rows, seq + 1))
    shifted = jnp.roll(toks, 1, axis=1)
    toks = jnp.where(rep, shifted, toks).astype(jnp.int32)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
    }
