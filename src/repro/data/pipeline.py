"""Host-side data pipeline: prefetch, shard, skip-ahead, stragglers.

A thin production shell over the stateless generators: a background
thread keeps `prefetch` batches ahead of the training loop (overlapping
host generation with device compute), batches are device_put with the
step's input sharding, and the cursor is just the step number — restart
= seek. A slow generation (straggler) is detected against an EMA budget
and logged; because batches are stateless the pipeline can also *drop*
a late batch and synthesize the next one without global resync.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax

from repro.obs import now


class Prefetcher:
    def __init__(
        self,
        make_batch: Callable[[int], Dict[str, jax.Array]],
        start_step: int = 0,
        prefetch: int = 2,
        straggler_factor: float = 3.0,
    ):
        self.make_batch = make_batch
        self.step = start_step
        self.prefetch = prefetch
        self.straggler_factor = straggler_factor
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._ema: Optional[float] = None
        self.stragglers = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            t0 = now()
            try:
                batch = self.make_batch(step)
            except Exception as e:  # pragma: no cover - defensive
                self._q.put(e)
                return
            dt = now() - t0
            if self._ema is None:
                self._ema = dt
            else:
                if dt > self.straggler_factor * self._ema:
                    self.stragglers += 1
                self._ema = 0.9 * self._ema + 0.1 * dt
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
