"""Fixed-size device leaf cache over a LeafStore.

A slot pool ``slots [S, max_leaf, series_len]`` lives on device; the
host keeps the leaf->slot map and runs CLOCK (second-chance) eviction.
Each search iteration calls :meth:`get_slots` with the leaf batch it is
about to score; hits just set the reference bit, misses are read from
disk (through the prefetcher when one is attached), stacked into ONE
host buffer and uploaded with ONE scatter — so the h2d traffic per
iteration is a single [misses, max_leaf, series_len] transfer, never a
per-leaf trickle.

Counters (``stats()``) are the bench currency of the paper's on-disk
regime: disk bytes actually read, h2d bytes shipped, hit/miss counts,
and how many of the misses the prefetcher had already staged.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .layout import LeafStore
from .prefetch import LeafPrefetcher


class DeviceLeafCache:
    def __init__(
        self,
        store: LeafStore,
        capacity_leaves: int,
        prefetcher: Optional[LeafPrefetcher] = None,
    ):
        if capacity_leaves < 1:
            raise ValueError("capacity_leaves must be >= 1")
        self.store = store
        self.capacity = int(capacity_leaves)
        self.prefetcher = prefetcher
        m, n = store.max_leaf, store.series_len
        self.slots = jnp.zeros((self.capacity, m, n),
                               jnp.dtype(store.data_dtype))
        self.slot_of: dict = {}                       # leaf -> slot
        self.owner = np.full(self.capacity, -1, np.int64)
        self.refbit = np.zeros(self.capacity, bool)
        self.hand = 0
        # counters
        self.hits = 0
        self.misses = 0
        self.bytes_read_sync = 0  # demand-path disk reads only; total
        #                           disk traffic = this + the attached
        #                           prefetcher's bytes_read (stats())
        self.bytes_h2d = 0       # padded slot bytes shipped to device
        self.prefetch_hits = 0   # misses served from the prefetcher

    # ------------------------------------------------------------------
    def _evict_one(self, pinned: set) -> int:
        """CLOCK: advance the hand, clearing reference bits, until an
        unpinned slot with refbit=0 comes up."""
        for _ in range(2 * self.capacity + 1):
            s = self.hand
            self.hand = (self.hand + 1) % self.capacity
            if s in pinned:
                continue
            if self.refbit[s]:
                self.refbit[s] = False
                continue
            if self.owner[s] >= 0:
                del self.slot_of[int(self.owner[s])]
            self.owner[s] = -1
            return s
        raise RuntimeError(
            f"cache thrash: all {self.capacity} slots pinned by one "
            f"iteration; raise capacity_leaves above the per-iteration "
            f"working set")

    def get_slots(self, leaves: Sequence[int]) -> np.ndarray:
        """Make every leaf resident; returns their slot numbers.

        ``leaves`` may contain duplicates (multiple query lanes visiting
        the same leaf) — each distinct leaf is read and uploaded once.
        """
        slots = np.empty(len(leaves), np.int64)
        pinned = {self.slot_of[lf] for lf in leaves if lf in self.slot_of}
        miss_leaves: List[int] = []
        miss_slots: List[int] = []
        assigned: dict = {}
        for i, lf in enumerate(leaves):
            lf = int(lf)
            if lf in self.slot_of:
                s = self.slot_of[lf]
                if lf in assigned:
                    pass             # dup within this batch: one miss
                else:
                    self.hits += 1
                self.refbit[s] = True
                slots[i] = s
                assigned.setdefault(lf, s)
                continue
            s = self._evict_one(pinned)
            pinned.add(s)
            self.slot_of[lf] = s
            self.owner[s] = lf
            self.refbit[s] = True
            assigned[lf] = s
            self.misses += 1
            miss_leaves.append(lf)
            miss_slots.append(s)
            slots[i] = s
        if miss_leaves:
            self._fill(miss_leaves, miss_slots)
        return slots

    def _fill(self, leaves: List[int], slot_ids: List[int]) -> None:
        m, n = self.store.max_leaf, self.store.series_len
        buf = np.zeros((len(leaves), m, n), self.store.data_dtype)
        for j, lf in enumerate(leaves):
            staged = None
            if self.prefetcher is not None:
                staged = self.prefetcher.take(lf)
            if staged is not None:
                buf[j] = staged
                self.prefetch_hits += 1  # bytes already counted by the
                #                          prefetcher thread
            else:
                self.store.read_leaf(lf, out=buf[j])
                self.bytes_read_sync += self.store.leaf_nbytes(lf)
        dev = jax.device_put(jnp.asarray(buf))
        self.slots = self.slots.at[jnp.asarray(slot_ids)].set(dev)
        self.bytes_h2d += buf.nbytes

    # ------------------------------------------------------------------
    @property
    def bytes_read(self) -> int:
        """TOTAL disk bytes this cache caused: demand reads plus every
        byte the attached prefetcher read (including speculation for
        leaves that were never consumed) — each byte counted once."""
        pf = self.prefetcher.bytes_read if self.prefetcher else 0
        return self.bytes_read_sync + pf

    def reset_counters(self) -> None:
        self.hits = 0
        self.misses = 0
        self.bytes_read_sync = 0
        self.bytes_h2d = 0
        self.prefetch_hits = 0
        if self.prefetcher is not None:
            self.prefetcher.bytes_read = 0
            self.prefetcher.leaves_read = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "capacity_leaves": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "bytes_read": self.bytes_read,
            "bytes_read_sync": self.bytes_read_sync,
            "bytes_h2d": self.bytes_h2d,
            "prefetch_hits": self.prefetch_hits,
        }
