"""Fixed-size device leaf cache over a LeafStore.

A slot pool ``slots [S, max_leaf, payload_cols]`` lives on device in
the store's ENCODED payload dtype (f32/bf16 rows, or uint8 PQ codes for
codec="pq" — decoding happens in the scoring step, never here); the
host keeps the leaf->slot map and runs CLOCK (second-chance) eviction.
Each search iteration calls :meth:`get_slots` with the leaf batch it is
about to score; hits just set the reference bit, misses are read from
disk (through the prefetcher when one is attached), stacked into ONE
host buffer and uploaded with ONE donated scatter — the pool buffer is
reused in place (O(misses) work per iteration), and the h2d traffic per
iteration is a single [misses, max_leaf, payload_cols] transfer, never
a per-leaf trickle.

Counters (``stats()``) are the bench currency of the paper's on-disk
regime: disk bytes actually read, h2d bytes shipped, hit/miss counts,
and how many of the misses the prefetcher had already staged. Since
PR 6 every counter is REGISTRY-BACKED (repro.obs.metrics): each cache
owns labeled ``store.cache.*`` counters in the process-wide registry —
``reset_counters()`` starts a new per-query window via counter marks
(the attribute/``stats()`` views report the window, preserving the old
reset semantics bit-for-bit) while the registry keeps process-lifetime
totals, so per-query resets can never erase fleet-level accounting.
The same window values feed the typed ``OocStats`` schema and the span
tree (store/ooc.py), so the three views cannot drift.

Hits are counted PER REQUEST: every occurrence of a leaf in the
``get_slots`` batch that did not trigger a disk read is a hit — so when
many query lanes visit the same leaf (the regime cooperative scoring
targets) the hit rate credits each lane. ``hits_distinct`` keeps the
per-distinct view (leaves resident at batch start).
"""

from __future__ import annotations

import functools
import itertools
import threading
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import REGISTRY

from .layout import LeafStore
from .prefetch import LeafPrefetcher

_cache_ids = itertools.count()


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_fill(slots, slot_ids, dev):
    """Donated in-place scatter of freshly read leaves into the pool.

    Donation is load-bearing: without it the whole [S, M, C] pool is
    copied every iteration — O(capacity) instead of O(misses)."""
    return slots.at[slot_ids].set(dev)


class DeviceLeafCache:
    def __init__(
        self,
        store: LeafStore,
        capacity_leaves: int,
        prefetcher: Optional[LeafPrefetcher] = None,
        name: Optional[str] = None,
    ):
        if capacity_leaves < 1:
            raise ValueError("capacity_leaves must be >= 1")
        self.store = store
        self.capacity = int(capacity_leaves)
        self.prefetcher = prefetcher
        self.name = name or f"cache{next(_cache_ids)}"
        m, c = store.max_leaf, store.payload_cols
        # the CLOCK state is lock-guarded (checked guarded_by
        # annotations, docs/ANALYSIS.md): the continuous-batching
        # ROADMAP item makes engine.query re-entrant, so concurrent
        # get_slots calls must see a consistent slot map. RLock —
        # get_slots holds it across _evict_one/_fill, which
        # re-acquire. Lock order (asserted by the obs lock-order
        # recorder in tests): cache._lock -> prefetcher._lock, never
        # the reverse.
        self._lock = threading.RLock()
        self.slots = jnp.zeros((self.capacity, m, c),
                               jnp.dtype(store.data_dtype))  # guarded_by: _lock
        self.slot_of: dict = {}      # leaf -> slot   # guarded_by: _lock
        self.owner = np.full(self.capacity, -1,
                             np.int64)                # guarded_by: _lock
        self.refbit = np.zeros(self.capacity, bool)   # guarded_by: _lock
        self.hand = 0                                 # guarded_by: _lock
        # registry-backed counters, windowed by reset_counters()
        lbl = {"cache": self.name}
        self._c_hits = REGISTRY.counter("store.cache.hits", **lbl)
        self._c_hits_distinct = REGISTRY.counter(
            "store.cache.hits_distinct", **lbl)
        self._c_misses = REGISTRY.counter("store.cache.misses", **lbl)
        self._c_bytes_read_sync = REGISTRY.counter(
            "store.cache.bytes_read_sync", **lbl)
        self._c_bytes_h2d = REGISTRY.counter(
            "store.cache.bytes_h2d", **lbl)
        self._c_prefetch_hits = REGISTRY.counter(
            "store.cache.prefetch_hits", **lbl)
        self._counters = (
            self._c_hits, self._c_hits_distinct, self._c_misses,
            self._c_bytes_read_sync, self._c_bytes_h2d,
            self._c_prefetch_hits)
        for ctr in self._counters:
            ctr.mark()  # a fresh cache starts a fresh window

    # windowed counter views (the pre-PR6 attribute surface)
    @property
    def hits(self) -> int:
        """Per-request: every non-read occurrence this window."""
        return self._c_hits.since_mark

    @property
    def hits_distinct(self) -> int:
        """Distinct leaves resident at batch start, this window."""
        return self._c_hits_distinct.since_mark

    @property
    def misses(self) -> int:
        """Distinct leaves read (disk or staged), this window."""
        return self._c_misses.since_mark

    @property
    def bytes_read_sync(self) -> int:
        """Demand-path disk reads only; total disk traffic = this +
        the attached prefetcher's bytes_read (stats())."""
        return self._c_bytes_read_sync.since_mark

    @property
    def bytes_h2d(self) -> int:
        """Padded slot bytes shipped to device, this window."""
        return self._c_bytes_h2d.since_mark

    @property
    def prefetch_hits(self) -> int:
        """Misses served from the prefetcher, this window."""
        return self._c_prefetch_hits.since_mark

    # ------------------------------------------------------------------
    def contains(self, leaf: int) -> bool:
        """True if the leaf is slot-resident right now (no side
        effects — unlike get_slots this neither touches the CLOCK
        reference bit nor counts a hit). The prefetch scheduler uses
        it to skip staging leaves that could never miss."""
        with self._lock:
            return int(leaf) in self.slot_of

    def _evict_one(self, pinned: set) -> int:
        """CLOCK: advance the hand, clearing reference bits, until an
        unpinned slot with refbit=0 comes up."""
        with self._lock:
            for _ in range(2 * self.capacity + 1):
                s = self.hand
                self.hand = (self.hand + 1) % self.capacity
                if s in pinned:
                    continue
                if self.refbit[s]:
                    self.refbit[s] = False
                    continue
                if self.owner[s] >= 0:
                    del self.slot_of[int(self.owner[s])]
                self.owner[s] = -1
                return s
        raise RuntimeError(
            f"cache thrash: all {self.capacity} slots pinned by one "
            "iteration; raise capacity_leaves above the per-iteration "
            "working set")

    def get_slots(self, leaves: Sequence[int]) -> np.ndarray:
        """Make every leaf resident; returns their slot numbers.

        ``leaves`` may contain duplicates (multiple query lanes visiting
        the same leaf) — each distinct leaf is read and uploaded once;
        every occurrence beyond the read counts as a (per-request) hit.

        The whole batch is one critical section: residency decisions,
        eviction, and the fill scatter happen under ``self._lock`` so
        a concurrent caller can never observe a slot map that points
        at not-yet-uploaded payload.
        """
        slots = np.empty(len(leaves), np.int64)
        with self._lock:
            pinned = {self.slot_of[lf] for lf in leaves
                      if lf in self.slot_of}
            miss_leaves: List[int] = []
            miss_slots: List[int] = []
            assigned: dict = {}
            for i, lf in enumerate(leaves):
                lf = int(lf)
                if lf in self.slot_of:
                    s = self.slot_of[lf]
                    # resident (or just filled earlier in this batch):
                    # served without a read -> per-request hit; only
                    # leaves resident BEFORE the batch count as
                    # distinct hits
                    self._c_hits.inc()
                    if lf not in assigned:
                        self._c_hits_distinct.inc()
                    self.refbit[s] = True
                    slots[i] = s
                    assigned.setdefault(lf, s)
                    continue
                s = self._evict_one(pinned)
                pinned.add(s)
                self.slot_of[lf] = s
                self.owner[s] = lf
                self.refbit[s] = True
                assigned[lf] = s
                self._c_misses.inc()
                miss_leaves.append(lf)
                miss_slots.append(s)
                slots[i] = s
            if miss_leaves:
                self._fill(miss_leaves, miss_slots)
        return slots

    def _fill(self, leaves: List[int], slot_ids: List[int]) -> None:
        m, c = self.store.max_leaf, self.store.payload_cols
        buf = np.zeros((len(leaves), m, c), self.store.data_dtype)
        for j, lf in enumerate(leaves):
            staged = None
            if self.prefetcher is not None:
                staged = self.prefetcher.take(lf)
            if staged is not None:
                buf[j] = staged
                self._c_prefetch_hits.inc()  # bytes already counted by
                #                              the prefetcher thread
            else:
                self.store.read_leaf(lf, out=buf[j])
                self._c_bytes_read_sync.inc(self.store.leaf_nbytes(lf))
        self._c_bytes_h2d.inc(buf.nbytes)  # real misses, not the pad
        # pad the batch to the next power of two by REPEATING the last
        # row (idempotent duplicate scatter) so the jitted scatter sees
        # O(log capacity) distinct shapes instead of one per miss count
        pad = 1 << (len(leaves) - 1).bit_length()
        ids_arr = np.empty(pad, np.int32)
        ids_arr[: len(leaves)] = slot_ids
        ids_arr[len(leaves):] = slot_ids[-1]
        if pad != len(leaves):
            buf = np.concatenate(
                [buf, np.broadcast_to(buf[-1], (pad - len(leaves),) +
                                      buf.shape[1:])])
        with self._lock:
            self.slots = _scatter_fill(
                self.slots, jnp.asarray(ids_arr), jnp.asarray(buf))

    # ------------------------------------------------------------------
    @property
    def bytes_read(self) -> int:
        """TOTAL disk bytes this cache caused: demand reads plus every
        byte the attached prefetcher read (including speculation for
        leaves that were never consumed) — each byte counted once."""
        pf = self.prefetcher.bytes_read if self.prefetcher else 0
        return self.bytes_read_sync + pf

    def reset_counters(self) -> None:
        """Start a fresh per-query measurement window (counter marks;
        the registry keeps the process-lifetime totals)."""
        for ctr in self._counters:
            ctr.mark()
        if self.prefetcher is not None:
            # quiesces first: a cold-pass read still in flight must not
            # land its bytes after the zeroing (bench_query_disk warm
            # stats would otherwise be polluted)
            self.prefetcher.reset_counters()

    def stats(self) -> dict:
        total = self.hits + self.misses
        distinct = self.hits_distinct + self.misses
        # repro: allow[stats-schema] pre-PR6 back-compat view of the SAME registry counters; search_ooc copies these fields into the typed OocStats field-for-field, so the two views cannot drift
        return {
            "capacity_leaves": self.capacity,
            "hits": self.hits,
            "hits_distinct": self.hits_distinct,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "hit_rate_distinct":
                self.hits_distinct / distinct if distinct else 0.0,
            "bytes_read": self.bytes_read,
            "bytes_read_sync": self.bytes_read_sync,
            "bytes_h2d": self.bytes_h2d,
            "prefetch_hits": self.prefetch_hits,
        }
