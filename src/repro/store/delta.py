"""The mutable tier: an LSM-style in-memory delta over frozen stores.

Everything below the engine is frozen-at-build (docs/ARCHITECTURE.md);
production serving needs writes at serving time (ROADMAP.md). This
module is the write-absorbing tier (docs/INGEST.md):

  active      a dict memtable absorbing ``insert(rows)`` /
              ``delete(ids)`` under one lock; reads never block on it
              longer than a snapshot copy.
  immutable   the memtable frozen by ``begin_freeze`` while background
              compaction builds it into a leaf-contiguous segment
              (codec-aware, through the ordinary ``save_index`` path —
              the engine owns that step); still served from snapshots
              until the segment is published.
  kills       id -> kill-sequence map. BOTH ``delete(id)`` and
              insert-of-an-existing-id record a kill at the current
              global sequence: every older copy of the id — in the
              frozen base shards (born at seq 0), in any compacted
              segment (born at its freeze seq), or in the immutable
              memtable (each row carries its insert seq) — is
              superseded. A frozen unit's copy of ``id`` is dead iff
              ``kills[id] > born_seq``; delete-then-reinsert needs no
              special case (the reinsert's kill masks the old copies,
              the new active row is newest by construction).

Search-side contract: :func:`search_snapshot` brute-scores a
snapshot's live rows with the SAME per-codec arithmetic as the frozen
store of that codec (fused expanded-form L2 over the f32 or bfloat16
image with image-space norms; the direct-difference form for pq, which
is what the exact re-rank reports) and returns sqrt'd (dists, ids)
shaped exactly like one more shard's answer — the engine folds it
through ``ops.topk_merge_unique``, whose distinct-id precondition the
kill rule guarantees (at most one live copy of any id across base +
segments + snapshot). That is what makes frozen+delta answers
bit-exact against a from-scratch rebuild holding the same live rows
(tests/test_delta.py).

Thread safety: every mutable field is guarded by ``_lock``; snapshots
copy out under the lock and are immutable afterwards, so queries never
hold the lock while scoring and compaction never blocks in-flight
queries (it swaps published state under the same lock).
"""

from __future__ import annotations

import threading
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import ops


class DeltaSnapshot(NamedTuple):
    """A consistent point-in-time view for ONE query: the live delta
    rows (active + still-live immutable), the kill map as of the same
    instant (masks for the frozen units MUST come from the same state
    the rows were read at, or a superseded base row and its
    replacement could both vanish), and the published segment list.
    Immutable after construction — scored without any lock."""
    rows: np.ndarray          # [m, n] f32 live delta rows
    ids: np.ndarray           # [m] int32
    kills: Dict[int, int]     # id -> kill seq (copy)
    kills_version: int        # monotone; keys per-unit dead-mask caches
    segments: Tuple           # published engine segment handles
    live_rows: int            # m

    def dead_mask(self, unit_ids: np.ndarray, born_seq: int,
                  pad_to: Optional[int] = None) -> np.ndarray:
        """[len(unit_ids)] bool: which of a frozen unit's rows this
        snapshot supersedes (kill seq newer than the unit's birth).
        ``pad_to`` right-pads with False up to a store's padded row
        count so ``ScoreCtx.dead[row_idx]`` can never index short."""
        uids = np.asarray(unit_ids)
        if not self.kills:
            mask = np.zeros(uids.shape[0], bool)
        else:
            kid = np.fromiter(self.kills.keys(), np.int64,
                              count=len(self.kills))
            kseq = np.fromiter(self.kills.values(), np.int64,
                               count=len(self.kills))
            killed = kid[kseq > born_seq]
            mask = np.isin(uids, killed) if killed.size \
                else np.zeros(uids.shape[0], bool)
        if pad_to is not None and pad_to > mask.shape[0]:
            mask = np.pad(mask, (0, pad_to - mask.shape[0]))
        return mask


class FreezeBatch(NamedTuple):
    """What ``begin_freeze`` hands the compactor: the immutable
    memtable's live rows and the birth sequence the resulting segment
    must carry. Deletes/reinserts that land DURING the build simply
    have kill seqs > born_seq and mask the published segment's copies
    — publishing stale rows is safe, never wrong."""
    rows: np.ndarray   # [m, n] f32
    ids: np.ndarray    # [m] int32
    born_seq: int


class DeltaTier:
    """The engine's write buffer. All public methods are thread-safe;
    ``insert``/``delete`` are O(rows) dict updates (no device work),
    so the serve front's write lane stays cheap."""

    def __init__(self, series_len: int, *, start_id: int = 0):
        self.series_len = int(series_len)
        self._lock = threading.RLock()
        self._seq = 0             # guarded_by: _lock (global mutation seq)
        self._active: Dict[int, tuple] = {}   # guarded_by: _lock id -> (row, seq)
        self._immutable: Optional[Dict[int, tuple]] = None  # guarded_by: _lock
        self._immutable_born = 0  # guarded_by: _lock
        self._kills: Dict[int, int] = {}      # guarded_by: _lock
        self._kills_version = 0   # guarded_by: _lock
        self._segments: Tuple = ()            # guarded_by: _lock
        self._next_id = int(start_id)         # guarded_by: _lock

    # ------------------------------------------------------------ writes
    def insert(self, rows, ids=None) -> np.ndarray:
        """Absorb rows; returns their ids (auto-allocated past the
        frozen id space when not supplied). Inserting an id that
        already exists ANYWHERE records a kill at the new sequence —
        the newest copy wins everywhere, older frozen copies are
        masked, an older active copy is simply replaced."""
        rows = np.ascontiguousarray(np.asarray(rows, np.float32))
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.shape[1] != self.series_len:
            raise ValueError(
                f"insert: rows have length {rows.shape[1]}, "
                f"store serves length {self.series_len}")
        with self._lock:
            if ids is None:
                ids = np.arange(self._next_id,
                                self._next_id + rows.shape[0],
                                dtype=np.int64)
                self._next_id += rows.shape[0]
            else:
                ids = np.asarray(ids, np.int64).reshape(-1)
                if ids.shape[0] != rows.shape[0]:
                    raise ValueError("insert: len(ids) != len(rows)")
                self._next_id = max(self._next_id, int(ids.max()) + 1)
            killed = 0
            for i, rid in enumerate(ids.tolist()):
                self._seq += 1
                # supersede any older copy of this id (frozen base,
                # segment, immutable — a fresh id's kill masks nothing)
                self._kills[rid] = self._seq
                killed += 1
                self._active[rid] = (rows[i], self._seq)
            self._kills_version += killed
        obs.REGISTRY.counter("delta.inserts").inc(rows.shape[0])
        obs.REGISTRY.gauge("delta.live_rows").set(self.live_rows())
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids everywhere (base, segments, memtables).
        Returns the number of ids processed; deleting an id that was
        never inserted is a no-op kill (masks nothing)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        with self._lock:
            for rid in ids.tolist():
                self._seq += 1
                self._kills[rid] = self._seq
                self._active.pop(rid, None)
            self._kills_version += ids.shape[0]
        obs.REGISTRY.counter("delta.deletes").inc(ids.shape[0])
        obs.REGISTRY.gauge("delta.live_rows").set(self.live_rows())
        return int(ids.shape[0])

    # ------------------------------------------------------------- reads
    def _live_items(self):
        """(id, row) pairs still live: all of active (newest by
        construction) + immutable rows whose insert seq outruns any
        kill. Takes the (reentrant) lock itself so callers already
        holding it stay atomic and bare callers stay safe."""
        with self._lock:
            out = []
            for rid, (row, seq) in self._active.items():
                out.append((rid, row))
            if self._immutable:
                for rid, (row, seq) in self._immutable.items():
                    if self._kills.get(rid, -1) <= seq:
                        out.append((rid, row))
            return out

    def live_rows(self) -> int:
        with self._lock:
            return len(self._live_items())

    def snapshot(self) -> DeltaSnapshot:
        with self._lock:
            items = self._live_items()
            if items:
                ids = np.asarray([rid for rid, _ in items], np.int64)
                rows = np.stack([row for _, row in items])
            else:
                ids = np.zeros((0,), np.int64)
                rows = np.zeros((0, self.series_len), np.float32)
            return DeltaSnapshot(
                rows=rows, ids=ids.astype(np.int32),
                kills=dict(self._kills),
                kills_version=self._kills_version,
                segments=self._segments,
                live_rows=int(ids.shape[0]))

    # -------------------------------------------------------- compaction
    def freeze_threshold_reached(self, max_rows: int) -> bool:
        with self._lock:
            return len(self._active) >= max_rows \
                and self._immutable is None

    def begin_freeze(self) -> Optional[FreezeBatch]:
        """Swap the active memtable to immutable and hand its live
        rows to the compactor. Returns None when there is nothing to
        compact or a freeze is already in flight (one compaction at a
        time)."""
        with self._lock:
            if self._immutable is not None or not self._active:
                return None
            self._immutable, self._active = self._active, {}
            self._immutable_born = self._seq
            live = [(rid, row) for rid, (row, seq)
                    in self._immutable.items()
                    if self._kills.get(rid, -1) <= seq]
            if not live:
                self._immutable = None
                return None
            ids = np.asarray([rid for rid, _ in live], np.int64)
            rows = np.stack([row for _, row in live])
            return FreezeBatch(rows=rows, ids=ids.astype(np.int32),
                               born_seq=self._immutable_born)

    def publish_segment(self, segment) -> None:
        """Swap the built segment in for the immutable memtable —
        one lock-held tuple append, so in-flight queries (their
        snapshots are copies) and new queries (they see segment OR
        immutable, never both, never neither) are both consistent."""
        with self._lock:
            self._segments = self._segments + (segment,)
            self._immutable = None
        obs.REGISTRY.counter("delta.compactions").inc()
        obs.REGISTRY.gauge("delta.live_rows").set(self.live_rows())

    def abort_freeze(self) -> None:
        """Compaction failed: fold the immutable memtable back into
        active (newest copy of an id wins) so no write is lost."""
        with self._lock:
            if self._immutable is None:
                return
            imm, self._immutable = self._immutable, None
            for rid, (row, seq) in imm.items():
                cur = self._active.get(rid)
                if cur is None or cur[1] < seq:
                    self._active[rid] = (row, seq)

    @property
    def kills_version(self) -> int:
        with self._lock:
            return self._kills_version

    def segments(self) -> Tuple:
        with self._lock:
            return self._segments


# ------------------------------------------------------------- scoring
def search_snapshot(snap: DeltaSnapshot, queries, k: int,
                    *, codec: str = "f32"):
    """Brute-score a snapshot's live rows as one more "shard": sqrt'd
    ([B, k] dists, [B, k] ids, -1 padded), ready for the engine's
    ``ops.topk_merge_unique`` fold. Per-codec arithmetic mirrors the
    frozen store of the same codec so frozen+delta equals a
    from-scratch rebuild bit-for-bit:

      f32    fused expanded-form L2 over f32 rows with f32 norms
             (refine_step's solo-raw corner over a resident pool).
      bf16   the same over the bfloat16 IMAGE of the rows, norms
             computed over the image — exactly what save_index
             persists and CachedStoreSource scores.
      pq     the direct-difference form — pq answers are reported by
             the exact re-rank (store/ooc._exact_rerank), which uses
             the cancellation-free difference form, and delta rows are
             trivially "exactly re-ranked".
    """
    b = queries.shape[0]
    qf = jnp.asarray(queries, jnp.float32)
    top_d = jnp.full((b, k), jnp.inf, jnp.float32)
    top_i = jnp.full((b, k), -1, jnp.int32)
    if snap.live_rows == 0:
        return top_d, top_i
    with obs.span("delta.search", lanes=b, rows=snap.live_rows):
        cand = jnp.broadcast_to(
            jnp.asarray(snap.ids, jnp.int32)[None, :],
            (b, snap.live_rows))
        if codec == "pq":
            diff = jnp.asarray(snap.rows) - qf[:, None, :]
            d = jnp.sum(diff * diff, axis=-1)
        else:
            rows = jnp.asarray(snap.rows)
            if codec == "bf16":
                rows = rows.astype(jnp.bfloat16)
            d = ops.sq_l2(qf, rows, ops.row_sq_norms(rows))
        top_d, top_i = ops.topk_merge(d, cand, top_d, top_i)
    return jnp.sqrt(top_d), top_i
