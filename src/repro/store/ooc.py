"""Out-of-core Algorithm 2: device filter, streamed refinement.

Semantics are IDENTICAL to core.search.search — same lower-bound
kernel, same lazy-frontier visit order (bit-equal to the stable argsort
order; the refill threshold proof is shared with search_impl, see
docs/PERF.md), same candidate layout per iteration ([V leaves x
max_leaf positions] per lane, invalid positions masked to inf), same
partial-selection topk merges over the same cached row norms, same
stopping predicates evaluated in f32 — so the exact / epsilon /
delta-epsilon guarantees transfer untouched; the ONLY difference is
residency: payload rows are gathered from the DeviceLeafCache slot
pool (fed from disk) instead of an HBM-resident data array.

Control flow moves from lax.while_loop to a host loop because each
iteration performs I/O. The host loop:

  1. computes this iteration's leaf batch from the (host) visit order;
  2. makes those leaves cache-resident (one batched h2d upload);
  3. schedules NEXT iteration's predicted leaves on the prefetcher, so
     the disk reads overlap the device scoring it is about to launch;
  4. runs the jitted refine step (gather from slots -> decode/score ->
     topk merge) on device;
  5. pulls back the per-lane kth-best and evaluates the paper's
     stopping predicates in numpy f32 (bit-identical arithmetic to the
     device f32 ops of the in-memory loop).

Codecs (store format v2).  The refine step decodes-then-scores the
ENCODED slots: f32 slots score directly, bf16 slots upcast inside the
fused L2 (bit-exact to in-memory search over the bfloat16 index), and
codec="pq" slots hold uint8 codes that are ADC-scored on device via the
kernels/pq_adc one-hot MXU trick — the loop then tracks padded row
POSITIONS and finishes with an exact re-rank: the final candidate pool
(``rerank``*k per lane) is re-scored in f32 against raw rows read from
``exact.bin``, so the reported distances are exact for the returned
neighbors and the epsilon/delta-epsilon guarantee checks survive the
lossy payload. Carve-out: the EXACT (epsilon=0) guarantee does NOT
survive pq — the stop predicate's kth-best is an ADC approximation
that can prune the true neighbor's leaf early; search_ooc warns if
asked for it.

Cooperative scoring (``share_gathers=True``) mirrors search_impl's
in-memory branch: every iteration's gathered slots are scored against
ALL query lanes in one MXU matmul instead of only the lane that
requested them. Extra candidates can only improve a lane's top-k, so
every guarantee is preserved, while each lane's best-so-far tightens
from the whole batch's I/O — per-query bytes-read drops as the batch
grows (for pq this is ONE [B, m*K] x [m*K, rows] matmul per iteration).
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histogram import r_delta
from repro.core.search import (INF, SearchResult, default_frontier,
                               dup_leaf_mask, frontier_select)
from repro.core.summaries.pq import adc_lut_batch
from repro.kernels import ops

from .cache import DeviceLeafCache
from .layout import LeafStore
from .prefetch import LeafPrefetcher


class OocResult(NamedTuple):
    result: SearchResult
    stats: dict


@jax.jit
def _filter_stage(resident, q):
    """Lower bound every leaf (device). The visit order is NOT fully
    argsorted here any more — the lazy frontier partially selects it
    rank window by rank window (_frontier_refill)."""
    q_sum = resident.summarize_queries(q)
    return ops.box_mindist(
        q_sum, resident.box_lo, resident.box_hi, resident.weights)


# the SAME visit-order primitive search_impl refills with (bit-exact
# in-memory/OOC parity by construction), jitted for the host loop
_frontier_refill = jax.jit(frontier_select, static_argnames=("f",))


@jax.jit
def _refine_step(qf, slots, flat_slot_idx, row_idx, top_d, top_i,
                 valid, ids, row_norms):
    """One iteration's scoring: gather rows from the slot pool, fused
    L2 (cached row norms) against every lane, O(k) merge into the
    running top-k. Mirrors the non-share_gathers branch of
    core.search.search_impl exactly."""
    n = qf.shape[1]
    rows = slots.reshape(-1, n)[flat_slot_idx]       # [B, V*M, n]
    cand_ids = jnp.where(valid, ids[row_idx], -1)
    d = ops.sq_l2(qf, rows, row_norms[row_idx])
    d = jnp.where(valid, d, INF)
    top_d, top_i = ops.topk_merge(d, cand_ids, top_d, top_i)
    return top_d, top_i


@jax.jit
def _refine_step_shared(qf, slots, flat_slot_idx, row_idx, top_d,
                        top_i, pool_valid, ids, row_norms):
    """Cooperative scoring: pool the iteration's gathered slots and
    score every row against ALL query lanes, selecting each lane's
    2k candidates fused with the scoring (ops.coop_score_select — on
    TPU the [B, B*V*M] distance matrix never reaches HBM), then dedup
    merge. Mirrors the share_gathers branch of
    core.search.search_impl exactly (same op sequence -> bit-exact
    parity). ``pool_valid`` already excludes same-iteration duplicate
    leaf copies (the distinct-id precondition)."""
    n = qf.shape[1]
    k = top_d.shape[1]
    flat = flat_slot_idx.reshape(-1)
    rows = slots.reshape(-1, n)[flat]                # [B*V*M, n]
    fvalid = pool_valid.reshape(-1)
    flat_rows = row_idx.reshape(-1)
    cand_ids = jnp.where(fvalid, ids[flat_rows], -1)
    sel_d, sel_i = ops.coop_score_select(
        qf, rows, row_norms[flat_rows], cand_ids,
        min(2 * k, cand_ids.shape[0]))
    return ops.dedup_merge_topk(sel_d, sel_i, top_d, top_i)


@jax.jit
def _refine_step_pq(luts, slots, flat_slot_idx, row_idx, top_d, top_i,
                    valid):
    """PQ decode-and-score: gather uint8 codes from the slot pool, ADC
    against each lane's LUT (one-hot MXU trick in ops.pq_adc_batch),
    merge padded row POSITIONS (exact re-rank maps them to ids)."""
    mcols = slots.shape[-1]
    codes = slots.reshape(-1, mcols)[flat_slot_idx]  # [B, V*M, m]
    cand_pos = jnp.where(valid, row_idx, -1)
    d = ops.pq_adc_batch(codes, luts)
    d = jnp.where(valid, d, INF)
    return ops.topk_merge(d, cand_pos, top_d, top_i)


@jax.jit
def _refine_step_pq_shared(luts, slots, flat_slot_idx, row_idx, top_d,
                           top_i, pool_valid):
    """Cooperative PQ scoring: ONE [B, m*K] x [m*K, rows] matmul scores
    every gathered code row against all query lanes; selection-based
    dedup merge keeps per-iteration merge cost O(k). ``pool_valid``
    already excludes same-iteration duplicate leaf copies."""
    mcols = slots.shape[-1]
    flat = flat_slot_idx.reshape(-1)
    codes = slots.reshape(-1, mcols)[flat]           # [B*V*M, m]
    fvalid = pool_valid.reshape(-1)
    cand_pos = jnp.where(fvalid, row_idx.reshape(-1), -1)
    d = ops.pq_adc_batch(codes, luts)                # [B, B*V*M]
    d = jnp.where(fvalid[None, :], d, INF)
    # cand_pos is lane-invariant -> topk_merge_unique's fast 1-D path
    return ops.topk_merge_unique(d, cand_pos, top_d, top_i)


def _exact_rerank(store: LeafStore, qf, top_d, top_i, k: int):
    """Re-score the PQ candidate pool (padded row positions) in f32
    against raw rows from exact.bin; return exact top-k (d_sq, ids)
    plus the re-rank bytes read. Tiny random reads — each distinct
    candidate row is read once for the whole batch."""
    pos = np.asarray(top_i)                          # [B, kk]
    uniq = np.unique(pos[pos >= 0])
    n = store.series_len
    if uniq.size == 0:
        return top_d[:, :k], top_i[:, :k], 0
    rows = np.asarray(store.read_rows_exact(uniq), np.float32)
    rerank_bytes = int(uniq.size) * n \
        * int(np.dtype(store.exact_mmap.dtype
                       if store.exact_mmap is not None
                       else store.mmap.dtype).itemsize)
    gather = np.searchsorted(uniq, np.clip(pos, 0, None))
    cand = rows[gather]                              # [B, kk, n]
    # direct difference form, not the expanded |q|^2-2qx+|x|^2: the
    # expanded form loses ~1e-3 absolute accuracy to cancellation at
    # near-zero distances, which would break the "reported distances
    # are exact" contract of the re-rank (and the guarantee checks
    # when a query coincides with a stored series); the candidate
    # pool is tiny so the elementwise cost is irrelevant
    diff = jnp.asarray(cand) - jnp.asarray(qf)[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)
    d = jnp.where(jnp.asarray(pos >= 0), d, INF)
    ids_h = np.asarray(store.resident.ids)
    cids = np.where(pos >= 0,
                    ids_h[np.clip(pos, 0, ids_h.shape[0] - 1)], -1)
    sd, si = jax.lax.sort((d, jnp.asarray(cids, jnp.int32)), num_keys=1)
    return sd[:, :k], si[:, :k], rerank_bytes


def search_ooc(
    store: LeafStore,
    queries: jax.Array,  # [B, n]
    k: int,
    *,
    delta: float = 1.0,
    epsilon: float = 0.0,
    nprobe: Optional[int] = None,
    visit_batch: int = 1,
    cache: Optional[DeviceLeafCache] = None,
    cache_leaves: Optional[int] = None,
    prefetch: bool = True,
    share_gathers: bool = False,
    rerank: int = 4,
    frontier: Optional[int] = None,
) -> OocResult:
    """k-NN over an on-disk index without device-resident raw data.

    Pass ``cache`` to reuse (and warm) a cache across calls, or
    ``cache_leaves`` to size a fresh one; default is 1/8 of the leaves
    (clamped to at least one iteration's working set).
    ``prefetch=False`` disables speculative scheduling for this call —
    including on a prefetcher already attached to a supplied cache —
    so stats measure pure demand-path reads.
    ``share_gathers=True`` scores every gathered slot against all query
    lanes (cooperative batching — module docstring). For codec="pq"
    stores, ``rerank``*k candidates per lane are kept through the ADC
    loop and exactly re-ranked against raw rows at the end.
    ``frontier`` tunes the lazy visit-order window width (None ->
    core.search.default_frontier, widened to cover the prefetch
    lookahead); any width emits the same visit order.
    """
    res = store.resident
    b, n = queries.shape
    L = res.num_leaves
    m = res.max_leaf
    v = int(visit_batch)
    per_iter = b * v  # worst-case distinct leaves one iteration pins

    own_prefetcher = None
    if cache is None:
        if cache_leaves is None:
            cache_leaves = max(L // 8, 1)
        cache_leaves = min(max(cache_leaves, per_iter), max(L, 1))
        cache = DeviceLeafCache(store, cache_leaves)
    if prefetch and cache.prefetcher is None:
        own_prefetcher = LeafPrefetcher(store)
        cache.prefetcher = own_prefetcher
    pf_used = cache.prefetcher

    pq = store.codec == "pq"
    kk = k * max(1, int(rerank)) if pq else k
    luts = None
    if pq:
        if store.codebook is None:
            raise ValueError("codec='pq' store has no codebook")
        if epsilon == 0.0 and nprobe is None:
            # the stopping predicate compares EXACT leaf lower bounds
            # against the ADC (approximate) kth-best, which can
            # underestimate and prune the true NN's leaf before it is
            # visited; the re-rank only rescores pooled candidates and
            # cannot recover it — so epsilon=0 is NOT exact under pq.
            warnings.warn(
                "codec='pq' cannot honor the exact (epsilon=0) "
                "guarantee: ADC-scored stopping may prune the true "
                "neighbor's leaf. Use epsilon>0 (the epsilon/"
                "delta-epsilon checks hold after the exact re-rank), "
                "nprobe, or a lossless codec.", UserWarning,
                stacklevel=2)
        luts = adc_lut_batch(store.codebook, queries)

    lb_sq_d = _filter_stage(res, queries)  # [B, L], stays on device

    # lazy frontier (host mirror of search_impl's): F covers this
    # iteration's visits, the next_lb probe AND the prefetch lookahead
    F = min(max(default_frontier(L, v), 2 * v), L) if frontier is None \
        else min(max(int(frontier), min(2 * v, L)), L)
    lane2 = np.arange(b)[:, None]
    fr_lb = np.full((b, F), np.inf, np.float32)
    fr_id = np.zeros((b, F), np.int64)
    fpos = np.full(b, F, np.int64)           # empty -> fill on entry
    thr_lb = np.full(b, -1.0, np.float32)
    thr_id = np.full(b, -1, np.int64)

    eps_mult = np.float32((1.0 + epsilon) ** 2)
    rd = float(r_delta(res.hist, delta, res.n_total))
    rd_sq = np.float32(rd) * np.float32(rd)
    max_rank = L if nprobe is None else min(nprobe, L)

    qf = jnp.asarray(queries, jnp.float32)
    top_d = jnp.full((b, kk), INF)
    top_i = jnp.full((b, kk), -1, jnp.int32)
    rank = np.zeros(b, np.int64)
    active = np.ones(b, bool)
    leaves_visited = np.zeros(b, np.int64)
    rows_scanned = np.zeros(b, np.int64)

    offs = store.offsets_h
    sizes = offs[1:] - offs[:-1]
    pos = np.arange(m)[None, None, :]
    iters = 0

    def frontier_leaves(first):
        """[B, V] leaf ids from frontier positions ``first`` (clamped
        to the window; callers mask out-of-rank slots via in_range,
        like the device body's clamped reads)."""
        ppos = np.minimum(first[:, None] + np.arange(v)[None, :], F - 1)
        return fr_id[lane2, ppos]

    def pool_dup_mask(leaf, in_range):
        """[B, V] True where the slot repeats a leaf already pooled by
        an earlier in-range slot this iteration — the SAME
        core.search.dup_leaf_mask the in-memory cooperative branch
        uses, so both pools are identical by construction (the [B, V]
        operands are tiny, the device round-trip is noise next to the
        scoring step)."""
        return np.asarray(dup_leaf_mask(jnp.asarray(leaf),
                                        jnp.asarray(in_range)))

    try:
        while active.any():
            # refill frontiers running too low to cover this
            # iteration + the prefetch lookahead (amortized: once per
            # floor(F/v) iterations per lane)
            need = active & (fpos > F - 2 * v)
            if need.any():
                nlb, nid = _frontier_refill(
                    lb_sq_d, jnp.asarray(thr_lb),
                    jnp.asarray(thr_id, jnp.int32), F)
                fr_lb[need] = np.asarray(nlb)[need]
                fr_id[need] = np.asarray(nid)[need]
                fpos[need] = 0

            rk = rank[:, None] + np.arange(v)[None, :]
            in_range = (rk < max_rank) & active[:, None]
            leaf = frontier_leaves(fpos)
            # full per-lane request list (dups included) so the cache's
            # per-request hit accounting credits lanes sharing a leaf
            needed = leaf[in_range]
            slots = cache.get_slots(needed.tolist())
            slot_of = dict(zip(needed.tolist(), slots.tolist()))

            # overlap: stage the leaves the NEXT iteration will want
            # while the device scores this one (skip leaves already
            # cache-resident — a warm cache must not touch the disk).
            # prefetch=False disables scheduling even on an attached
            # prefetcher: callers use it to measure pure demand reads.
            if prefetch and cache.prefetcher is not None:
                nxt_rank = np.minimum(rank + v, max_rank)
                nxt_rk = nxt_rank[:, None] + np.arange(v)[None, :]
                nxt_in = (nxt_rk < max_rank) & active[:, None]
                nxt_leaf = frontier_leaves(fpos + v)
                nxt = [int(lf) for lf in np.unique(nxt_leaf[nxt_in])
                       if int(lf) not in cache.slot_of]
                if nxt:
                    cache.prefetcher.schedule(nxt)

            # candidate layout mirrors search_impl: [B, V, M] -> [B, V*M]
            slot_arr = np.zeros_like(leaf)
            for lf, s in slot_of.items():
                slot_arr[leaf == lf] = s
            start = offs[leaf]                         # [B, V]
            valid = (pos < sizes[leaf][:, :, None]) & in_range[:, :, None]
            row_idx = np.minimum(start[:, :, None] + pos,
                                 offs[-1] - 1 if offs[-1] else 0)
            flat_slot = slot_arr[:, :, None] * m + pos

            flat_slot_j = jnp.asarray(
                flat_slot.reshape(b, v * m), jnp.int32)
            row_idx_j = jnp.asarray(row_idx.reshape(b, v * m), jnp.int32)
            valid_j = jnp.asarray(valid.reshape(b, v * m))
            if share_gathers:
                # same-iteration duplicate leaf copies leave the pool
                # (per-lane visit accounting below still uses ``valid``)
                dup = pool_dup_mask(leaf, in_range)
                pool_valid_j = jnp.asarray(
                    (valid & ~dup[:, :, None]).reshape(b, v * m))
            if pq and share_gathers:
                top_d, top_i = _refine_step_pq_shared(
                    luts, cache.slots, flat_slot_j, row_idx_j,
                    top_d, top_i, pool_valid_j)
            elif pq:
                top_d, top_i = _refine_step_pq(
                    luts, cache.slots, flat_slot_j, row_idx_j,
                    top_d, top_i, valid_j)
            elif share_gathers:
                top_d, top_i = _refine_step_shared(
                    qf, cache.slots, flat_slot_j, row_idx_j,
                    top_d, top_i, pool_valid_j, res.ids,
                    res.row_norms)
            else:
                top_d, top_i = _refine_step(
                    qf, cache.slots, flat_slot_j, row_idx_j,
                    top_d, top_i, valid_j, res.ids, res.row_norms)

            leaves_visited += np.where(active, in_range.sum(1), 0)
            rows_scanned += np.where(active, valid.sum((1, 2)), 0)

            rank_next = np.minimum(rank + v, max_rank)
            exhausted = rank_next >= max_rank
            next_lb = np.where(
                exhausted, np.float32(np.inf),
                fr_lb[np.arange(b), np.minimum(fpos + v, F - 1)],
            ).astype(np.float32)
            bsf = np.asarray(top_d[:, k - 1])          # f32, sync point
            stop = (next_lb * eps_mult > bsf) \
                | (bsf <= eps_mult * rd_sq) \
                | exhausted
            # refill threshold <- last rank consumed this iteration
            last = np.minimum(fpos + v - 1, F - 1)
            thr_lb = np.where(active, fr_lb[np.arange(b), last], thr_lb)
            thr_id = np.where(active, fr_id[np.arange(b), last], thr_id)
            fpos = fpos + v
            active = active & ~stop
            rank = rank_next
            iters += 1
    finally:
        if own_prefetcher is not None:
            own_prefetcher.close()
            if cache.prefetcher is own_prefetcher:
                cache.prefetcher = None

    rerank_bytes = 0
    if pq:
        top_d, top_i, rerank_bytes = _exact_rerank(
            store, qf, top_d, top_i, k)

    result = SearchResult(
        dists=jnp.sqrt(top_d),
        ids=top_i,
        leaves_visited=jnp.asarray(leaves_visited, jnp.int32),
        rows_scanned=jnp.asarray(rows_scanned, jnp.int32),
        lb_computed=jnp.int32(L),
    )
    stats = dict(cache.stats())
    stats["iterations"] = iters
    stats["codec"] = store.codec
    stats["share_gathers"] = bool(share_gathers)
    stats["dataset_bytes"] = store.dataset_nbytes
    stats["bytes_read_rerank"] = rerank_bytes
    stats["bytes_read"] += rerank_bytes
    if pf_used is not None:
        if cache.prefetcher is None:  # transient pf already detached:
            stats["bytes_read"] += pf_used.bytes_read  # fold bytes in
        stats["prefetch_bytes_read"] = pf_used.bytes_read
        stats["prefetch_leaves_read"] = pf_used.leaves_read
    return OocResult(result=result, stats=stats)
