"""Out-of-core Algorithm 2: device filter, streamed refinement.

Semantics are IDENTICAL to core.search.search — same lower-bound
kernel, same argsort visit order, same candidate layout per iteration
([V leaves x max_leaf positions] per lane, invalid positions masked to
inf), same topk_merge, same stopping predicates evaluated in f32 — so
the exact / epsilon / delta-epsilon guarantees transfer untouched; the
ONLY difference is residency: raw rows are gathered from the
DeviceLeafCache slot pool (fed from disk) instead of an HBM-resident
data array.

Control flow moves from lax.while_loop to a host loop because each
iteration performs I/O. The host loop:

  1. computes this iteration's leaf batch from the (host) visit order;
  2. makes those leaves cache-resident (one batched h2d upload);
  3. schedules NEXT iteration's predicted leaves on the prefetcher, so
     the disk reads overlap the device scoring it is about to launch;
  4. runs the jitted refine step (gather from slots -> fused L2 ->
     topk merge) on device;
  5. pulls back the per-lane kth-best and evaluates the paper's
     stopping predicates in numpy f32 (bit-identical arithmetic to the
     device f32 ops of the in-memory loop).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histogram import r_delta
from repro.core.search import INF, SearchResult, _batched_sq_l2
from repro.kernels import ops

from .cache import DeviceLeafCache
from .layout import LeafStore
from .prefetch import LeafPrefetcher


class OocResult(NamedTuple):
    result: SearchResult
    stats: dict


@jax.jit
def _filter_stage(resident, q):
    """Lower bound every leaf and derive the visit order (device)."""
    q_sum = resident.summarize_queries(q)
    lb_sq = ops.box_mindist(
        q_sum, resident.box_lo, resident.box_hi, resident.weights)
    order = jnp.argsort(lb_sq, axis=1)
    lb_sorted = jnp.take_along_axis(lb_sq, order, axis=1)
    return order, lb_sorted


@jax.jit
def _refine_step(qf, slots, flat_slot_idx, row_idx, top_d, top_i,
                 valid, ids):
    """One iteration's scoring: gather rows from the slot pool, fused
    L2 against every lane, merge into the running top-k. Mirrors the
    non-share_gathers branch of core.search.search_impl exactly."""
    b = qf.shape[0]
    n = qf.shape[1]
    rows = slots.reshape(-1, n)[flat_slot_idx]       # [B, V*M, n]
    cand_ids = jnp.where(valid, ids[row_idx], -1)
    d = _batched_sq_l2(qf, rows)
    d = jnp.where(valid, d, INF)
    top_d, top_i = ops.topk_merge(d, cand_ids, top_d, top_i)
    return top_d, top_i


def search_ooc(
    store: LeafStore,
    queries: jax.Array,  # [B, n]
    k: int,
    *,
    delta: float = 1.0,
    epsilon: float = 0.0,
    nprobe: Optional[int] = None,
    visit_batch: int = 1,
    cache: Optional[DeviceLeafCache] = None,
    cache_leaves: Optional[int] = None,
    prefetch: bool = True,
) -> OocResult:
    """k-NN over an on-disk index without device-resident raw data.

    Pass ``cache`` to reuse (and warm) a cache across calls, or
    ``cache_leaves`` to size a fresh one; default is 1/8 of the leaves
    (clamped to at least one iteration's working set).
    """
    res = store.resident
    b, n = queries.shape
    L = res.num_leaves
    m = res.max_leaf
    v = int(visit_batch)
    per_iter = b * v  # worst-case distinct leaves one iteration pins

    own_prefetcher = None
    if cache is None:
        if cache_leaves is None:
            cache_leaves = max(L // 8, 1)
        cache_leaves = min(max(cache_leaves, per_iter), max(L, 1))
        cache = DeviceLeafCache(store, cache_leaves)
    if prefetch and cache.prefetcher is None:
        own_prefetcher = LeafPrefetcher(store)
        cache.prefetcher = own_prefetcher
    pf_used = cache.prefetcher

    order_d, lb_sorted_d = _filter_stage(res, queries)
    order = np.asarray(order_d)
    lb_sorted = np.asarray(lb_sorted_d)

    eps_mult = np.float32((1.0 + epsilon) ** 2)
    rd = float(r_delta(res.hist, delta, res.n_total))
    rd_sq = np.float32(rd) * np.float32(rd)
    max_rank = L if nprobe is None else min(nprobe, L)

    qf = jnp.asarray(queries, jnp.float32)
    top_d = jnp.full((b, k), INF)
    top_i = jnp.full((b, k), -1, jnp.int32)
    rank = np.zeros(b, np.int64)
    active = np.ones(b, bool)
    leaves_visited = np.zeros(b, np.int64)
    rows_scanned = np.zeros(b, np.int64)

    offs = store.offsets_h
    sizes = offs[1:] - offs[:-1]
    pos = np.arange(m)[None, None, :]
    iters = 0

    def iteration_leaves(ranks, act):
        """[B, V] leaf per visit slot + in_range mask, like the device
        body: ranks clamped to L-1, masked by max_rank and activity."""
        rk = ranks[:, None] + np.arange(v)[None, :]
        in_range = (rk < max_rank) & act[:, None]
        return order[np.arange(b)[:, None], np.minimum(rk, L - 1)], \
            in_range

    try:
        while active.any():
            leaf, in_range = iteration_leaves(rank, active)
            needed = np.unique(leaf[in_range])
            slots = cache.get_slots(needed.tolist())
            slot_of = dict(zip(needed.tolist(), slots.tolist()))

            # overlap: stage the leaves the NEXT iteration will want
            # while the device scores this one (skip leaves already
            # cache-resident — a warm cache must not touch the disk)
            if cache.prefetcher is not None:
                nxt_rank = np.minimum(rank + v, max_rank)
                nxt_leaf, nxt_in = iteration_leaves(nxt_rank, active)
                nxt = [int(lf) for lf in np.unique(nxt_leaf[nxt_in])
                       if int(lf) not in cache.slot_of]
                if nxt:
                    cache.prefetcher.schedule(nxt)

            # candidate layout mirrors search_impl: [B, V, M] -> [B, V*M]
            slot_arr = np.zeros_like(leaf)
            for lf, s in slot_of.items():
                slot_arr[leaf == lf] = s
            start = offs[leaf]                         # [B, V]
            valid = (pos < sizes[leaf][:, :, None]) & in_range[:, :, None]
            row_idx = np.minimum(start[:, :, None] + pos,
                                 offs[-1] - 1 if offs[-1] else 0)
            flat_slot = slot_arr[:, :, None] * m + pos

            top_d, top_i = _refine_step(
                qf, cache.slots,
                jnp.asarray(flat_slot.reshape(b, v * m), jnp.int32),
                jnp.asarray(row_idx.reshape(b, v * m), jnp.int32),
                top_d, top_i,
                jnp.asarray(valid.reshape(b, v * m)),
                res.ids,
            )

            leaves_visited += np.where(active, in_range.sum(1), 0)
            rows_scanned += np.where(active, valid.sum((1, 2)), 0)

            rank_next = np.minimum(rank + v, max_rank)
            exhausted = rank_next >= max_rank
            next_lb = np.where(
                exhausted, np.float32(np.inf),
                lb_sorted[np.arange(b), np.minimum(rank_next, L - 1)],
            ).astype(np.float32)
            bsf = np.asarray(top_d[:, k - 1])          # f32, sync point
            stop = (next_lb * eps_mult > bsf) \
                | (bsf <= eps_mult * rd_sq) \
                | exhausted
            active = active & ~stop
            rank = rank_next
            iters += 1
    finally:
        if own_prefetcher is not None:
            own_prefetcher.close()
            if cache.prefetcher is own_prefetcher:
                cache.prefetcher = None

    result = SearchResult(
        dists=jnp.sqrt(top_d),
        ids=top_i,
        leaves_visited=jnp.asarray(leaves_visited, jnp.int32),
        rows_scanned=jnp.asarray(rows_scanned, jnp.int32),
        lb_computed=jnp.int32(L),
    )
    stats = dict(cache.stats())
    stats["iterations"] = iters
    stats["dataset_bytes"] = int(store.mmap.nbytes)
    if pf_used is not None:
        if cache.prefetcher is None:  # transient pf already detached:
            stats["bytes_read"] += pf_used.bytes_read  # fold bytes in
        stats["prefetch_bytes_read"] = pf_used.bytes_read
        stats["prefetch_leaves_read"] = pf_used.leaves_read
    return OocResult(result=result, stats=stats)
