"""Out-of-core Algorithm 2: device filter, streamed refinement.

Semantics are IDENTICAL to core.search.search — this module does not
mirror the refinement loop, it DRIVES the same one: frontier
tick/advance, candidate layout, duplicate-leaf masking, the
codec-dispatched score+merge step and the stopping predicates are all
the shared core/refine.py functions (search_impl traces them inside
its lax.while_loop; this host loop calls them jitted), so the exact /
epsilon / delta-epsilon guarantees transfer untouched. The ONLY
difference is residency, supplied by two LeafSource implementations:

  CachedStoreSource   f32/bf16 leaves gathered from the
                      DeviceLeafCache slot pool (fed from disk through
                      the prefetcher); fused-L2 scoring over the
                      ENCODED slots (bf16 upcasts inside the kernel —
                      bit-exact to in-memory search over the bfloat16
                      index).
  PQSource            uint8 PQ codes ADC-scored on device (the
                      kernels/pq_adc one-hot MXU trick); the loop
                      tracks padded row POSITIONS and ``finalize`` runs
                      the exact re-rank against ``exact.bin`` so the
                      epsilon/delta-epsilon guarantee checks survive
                      the lossy payload. Carve-out: the EXACT
                      (epsilon=0) guarantee does NOT survive pq — the
                      stop predicate's kth-best is an ADC approximation
                      that can prune the true neighbor's leaf early;
                      search_ooc warns if asked for it.

Control flow moves from lax.while_loop to a host loop because each
iteration performs I/O. The host loop:

  1. ticks the (shared) frontier for this iteration's leaf window;
  2. makes those leaves cache-resident (one batched h2d upload);
  3. schedules the next ``prefetch_depth`` visit windows on the
     prefetcher, so the disk reads overlap the device scoring it is
     about to launch;
  4. runs the jitted shared refine step (gather from slots ->
     decode/score -> topk merge) on device;
  5. pulls back the per-lane kth-best and evaluates the shared
     stopping predicates in numpy f32 (bit-identical arithmetic to the
     device f32 ops of the in-memory loop).

Cooperative scoring (``share_gathers=True``) is search_impl's
cooperative branch verbatim — the same refine_step corner with the
cache slot pool as the gather pool (for pq: the fused
ops.pq_adc_select kernel, which on TPU streams the uint8 codes
through the one-hot MXU contraction tile by tile so the [B, B*V*M]
ADC matrix never reaches HBM — docs/PERF.md §4).
"""

from __future__ import annotations

import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import refine
from repro.core.histogram import r_delta
from repro.core.refine import INF, Gathered, ScoreCtx, default_frontier
from repro.core.search import SearchResult
from repro.core.summaries.pq import adc_lut_batch
from repro.obs import OocStats

from .cache import DeviceLeafCache
from .layout import LeafStore
from .prefetch import LeafPrefetcher


class OocResult(NamedTuple):
    result: SearchResult
    stats: OocStats


@jax.jit
def _filter_stage(resident, q):
    """Lower bound every leaf (device) — the shared filter pass; the
    visit order is partially selected from it window by window."""
    return refine.leaf_lower_bounds(resident, q)


# jitted host-loop entry points over the SHARED core primitives (the
# in-memory while_loop traces the same functions inline — bit-exact
# visit order / scoring / stopping parity by construction)
_frontier_refill = jax.jit(refine.frontier_select,
                           static_argnames=("f",))
_frontier_tick = jax.jit(refine.frontier_tick,
                         static_argnames=("v", "lookahead"))
_frontier_advance = jax.jit(refine.frontier_advance,
                            static_argnames=("v",))
_frontier_window = jax.jit(refine.frontier_window,
                           static_argnames=("offset", "v"))
_refine_step = jax.jit(refine.refine_step,
                       static_argnames=("share", "pq", "force_pallas"))
_coop_mask = jax.jit(refine.coop_mask)


class CachedStoreSource:
    """LeafSource over a LeafStore: leaves reach the device through a
    DeviceLeafCache (disk -> host buffer -> one batched h2d scatter),
    ``gather`` maps this iteration's window to cache slots, and
    ``prefetch`` hands the next windows to the attached prefetcher.
    Scoring is the shared refine_step with the slot pool as the gather
    pool (raw codecs: fused L2 over encoded slots)."""

    pq = False

    def __init__(self, store: LeafStore, cache: DeviceLeafCache, *,
                 prefetch: bool = True):
        self.store = store
        self.cache = cache
        self.prefetch_enabled = prefetch

    @property
    def resident(self):
        return self.store.resident

    def query_ctx(self, queries: jax.Array) -> ScoreCtx:
        res = self.store.resident
        return ScoreCtx(qf=jnp.asarray(queries, jnp.float32),
                        ids=res.ids, norms=res.row_norms, luts=None)

    def track_width(self, k: int) -> int:
        return k

    def gather(self, leaf: np.ndarray, ok: np.ndarray) -> Gathered:
        """Make the [B, V] window cache-resident and expose it as a
        refine_step gather pool. The full per-lane request list (dups
        included) feeds the cache so its per-request hit accounting
        credits lanes sharing a leaf."""
        m = self.store.max_leaf
        b, v = leaf.shape
        needed = leaf[ok]
        slots = self.cache.get_slots(needed.tolist())
        slot_of = dict(zip(needed.tolist(), slots.tolist()))
        slot_arr = np.zeros_like(leaf)
        for lf, s in slot_of.items():
            slot_arr[leaf == lf] = s
        gi = (slot_arr[:, :, None] * m
              + np.arange(m)[None, None, :]).reshape(b, v * m)
        row_idx, valid = refine.candidate_layout(
            self.resident.offsets, jnp.asarray(leaf, jnp.int32),
            jnp.asarray(ok), m, self.store.mmap.shape[0] - 1)
        pool = self.cache.slots.reshape(-1, self.store.payload_cols)
        return Gathered(pool=pool,
                        gather_idx=jnp.asarray(gi, jnp.int32),
                        row_idx=row_idx, valid=valid)

    def prefetch(self, windows) -> None:
        """Stage future visit windows ([(leaf [B, V], ok [B, V])],
        nearest first) on the attached prefetcher, skipping leaves
        already cache-resident — a warm cache must not touch the disk.
        ``prefetch=False`` disables scheduling even on an attached
        prefetcher: callers use it to measure pure demand reads."""
        pf = self.cache.prefetcher
        if not self.prefetch_enabled or pf is None:
            return
        for leaf_w, ok_w in windows:
            nxt = [int(lf) for lf in np.unique(leaf_w[ok_w])
                   if not self.cache.contains(int(lf))]
            if nxt:
                pf.schedule(nxt)

    def score(self, ctx, g, valid, top_d, top_i, *, share):
        return _refine_step(ctx, g.pool, g.gather_idx, g.row_idx,
                            valid, top_d, top_i, share=share,
                            pq=self.pq)

    def finalize(self, ctx, top_d, top_i, k: int):
        return top_d, top_i, 0


class PQSource(CachedStoreSource):
    """CachedStoreSource whose slots hold uint8 PQ codes: scoring is
    the refine_step pq corner (ADC LUTs in the query ctx, padded row
    positions as candidates) and ``finalize`` is the exact re-rank
    against raw exact.bin rows."""

    pq = True

    def __init__(self, store: LeafStore, cache: DeviceLeafCache, *,
                 rerank: int = 4, **kw):
        super().__init__(store, cache, **kw)
        if store.codebook is None:
            raise ValueError("codec='pq' store has no codebook")
        self.rerank = max(1, int(rerank))

    def query_ctx(self, queries: jax.Array) -> ScoreCtx:
        return ScoreCtx(qf=jnp.asarray(queries, jnp.float32),
                        ids=self.resident.ids, norms=None,
                        luts=adc_lut_batch(self.store.codebook, queries))

    def track_width(self, k: int) -> int:
        return k * self.rerank

    def finalize(self, ctx, top_d, top_i, k: int):
        return _exact_rerank(self.store, ctx.qf, top_d, top_i, k)


def _exact_rerank(store: LeafStore, qf, top_d, top_i, k: int):
    """Re-score the PQ candidate pool (padded row positions) in f32
    against raw rows from exact.bin; return exact top-k (d_sq, ids)
    plus the re-rank bytes read. Tiny random reads — each distinct
    candidate row is read once for the whole batch."""
    pos = np.asarray(top_i)                          # [B, kk]
    uniq = np.unique(pos[pos >= 0])
    n = store.series_len
    if uniq.size == 0:
        return top_d[:, :k], top_i[:, :k], 0
    rows = np.asarray(store.read_rows_exact(uniq), np.float32)
    rerank_bytes = int(uniq.size) * n \
        * int(np.dtype(store.exact_mmap.dtype
                       if store.exact_mmap is not None
                       else store.mmap.dtype).itemsize)
    gather = np.searchsorted(uniq, np.clip(pos, 0, None))
    cand = rows[gather]                              # [B, kk, n]
    # direct difference form, not the expanded |q|^2-2qx+|x|^2: the
    # expanded form loses ~1e-3 absolute accuracy to cancellation at
    # near-zero distances, which would break the "reported distances
    # are exact" contract of the re-rank (and the guarantee checks
    # when a query coincides with a stored series); the candidate
    # pool is tiny so the elementwise cost is irrelevant
    diff = jnp.asarray(cand) - jnp.asarray(qf)[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)
    d = jnp.where(jnp.asarray(pos >= 0), d, INF)
    ids_h = np.asarray(store.resident.ids)
    cids = np.where(pos >= 0,
                    ids_h[np.clip(pos, 0, ids_h.shape[0] - 1)], -1)
    sd, si = jax.lax.sort((d, jnp.asarray(cids, jnp.int32)), num_keys=1)
    return sd[:, :k], si[:, :k], rerank_bytes


def _host_refine(
    src, queries: jax.Array, k: int, *, delta: float, epsilon: float,
    nprobe: Optional[int], visit_batch: int, share_gathers: bool,
    frontier: Optional[int], prefetch_depth: int, fault=None,
    dead: Optional[jax.Array] = None, n_override: Optional[int] = None,
):
    """The host-driven refinement loop over a LeafSource — the same
    Algorithm 2 iteration search_impl runs under lax.while_loop,
    executed step by step so each iteration can perform I/O. Returns
    (SearchResult with SQUARED final pool pre-finalize sqrt applied,
    refinement telemetry dict, rerank_bytes).

    Telemetry is read-only observation of values the loop already
    syncs to host (active mask, ranks, bsf, next_lb) — it cannot
    change visit order, scoring, or stopping arithmetic. Spans are
    emitted only when tracing is enabled (obs.enabled()).

    ``fault`` is the serving-layer injection hook (duck-typed —
    serve/fault.FaultContext in production): ``fault.check("gather")``
    runs before every leaf-gather I/O and ``fault.check("score")``
    before every device scoring step, which is where injected faults
    fire and cooperative per-attempt deadlines are polled
    (docs/FAULT.md). ``fault=None`` (every non-chaos caller) adds no
    work to the loop.

    ``dead``/``n_override`` are the mutable-tier hooks
    (docs/INGEST.md): a [npad] bool tombstone mask folded into
    refine_step's validity, and the live joint row count substituted
    into r_delta (same contract as core.search.search_impl)."""
    res = src.resident
    b, n = queries.shape
    L = res.num_leaves
    v = int(visit_batch)
    depth = max(1, int(prefetch_depth))
    traced = obs.enabled()

    ctx = src.query_ctx(queries)
    if dead is not None:
        ctx = ctx._replace(dead=jnp.asarray(dead))
    with obs.span("ooc.filter", leaves=L, lanes=b):
        lb_sq = _filter_stage(res, queries)  # [B, L], stays on device
        if traced:  # make the span cover the device work it launched
            jax.block_until_ready(lb_sq)

    # frontier width F covers this iteration's visits, the next_lb
    # probe AND the prefetch lookahead (depth extra windows); ANY
    # width emits the same visit order (core/refine.py). F must
    # exceed the lookahead by at least one window — at F == lookahead
    # the refill condition (pos > F-1-lookahead) holds every
    # iteration and the amortized refill degenerates to one full
    # frontier_select per step
    la_want = (1 + depth) * v
    F = min(max(default_frontier(L, v), la_want + v), L) \
        if frontier is None \
        else min(max(int(frontier), min(la_want + v, L)), L)
    lookahead = min(la_want, F)
    fr = refine.frontier_init(b, F)

    eps_mult = np.float32((1.0 + epsilon) ** 2)
    rd = float(r_delta(
        res.hist, delta,
        res.n_total if n_override is None else n_override))
    rd_sq = np.float32(rd) * np.float32(rd)
    max_rank = L if nprobe is None else min(nprobe, L)

    kk = src.track_width(k)
    top_d = jnp.full((b, kk), INF)
    top_i = jnp.full((b, kk), -1, jnp.int32)
    rank = np.zeros(b, np.int64)
    active = np.ones(b, bool)
    leaves_visited = np.zeros(b, np.int64)
    rows_scanned = np.zeros(b, np.int64)
    iters = 0
    # refinement telemetry (read-only; see docstring)
    refills = 0
    stop_n = {"delta": 0, "epsilon": 0, "exhausted": 0}
    slack_sum = {"delta": 0.0, "epsilon": 0.0}
    slack_n = {"delta": 0, "epsilon": 0}

    while active.any():
        it_span = obs.span("ooc.iteration", iter=iters)
        it_span.__enter__()
        # the try/finally matters under fault injection: an exception
        # escaping mid-iteration (injected fault, attempt deadline)
        # must still pop this span off the thread's stack, or every
        # later span in this worker thread would nest under a corpse
        try:
            active_j = jnp.asarray(active)
            # mirror frontier_tick's refill predicate (same F/
            # lookahead/pos inputs) to count lane-refill events; pos
            # is host-read BEFORE the tick so the count observes,
            # never participates
            pos_host = np.asarray(fr.pos)
            refills += int(
                (active & (pos_host > F - 1 - lookahead)).sum())
            fr, leaf_j = _frontier_tick(fr, lb_sq, active_j,
                                        v=v, lookahead=lookahead)
            leaf = np.asarray(leaf_j)

            rk = rank[:, None] + np.arange(v)[None, :]
            in_range = rk < max_rank
            ok = in_range & active[:, None]
            if fault is not None:
                fault.check("gather")
            with obs.span("ooc.gather") as g_span:
                # demand-path (sync) reads only: the prefetcher thread
                # lands its bytes concurrently, so a cache.bytes_read
                # delta here would be racy — the root span carries the
                # authoritative total instead
                pre_read = src.cache.bytes_read_sync if traced else 0
                g = src.gather(leaf, ok)
                if traced:
                    g_span.set(bytes_read_sync=(
                        src.cache.bytes_read_sync - pre_read))

            # overlap: stage the next `depth` visit windows while the
            # device scores this one (nearest window first — it is
            # read first)
            windows = []
            for d in range(1, depth + 1):
                base = np.minimum(rank + d * v, max_rank)
                ok_d = ((base[:, None] + np.arange(v)[None, :])
                        < max_rank) & active[:, None]
                if ok_d.any():
                    windows.append(
                        (np.asarray(_frontier_window(fr, d * v, v)),
                         ok_d))
            src.prefetch(windows)

            if fault is not None:
                fault.check("score")
            with obs.span("ooc.score", lanes=int(active.sum())):
                if share_gathers:
                    pool_valid = _coop_mask(leaf_j, jnp.asarray(ok),
                                            g.valid)
                    top_d, top_i = src.score(ctx, g, pool_valid, top_d,
                                             top_i, share=True)
                else:
                    top_d, top_i = src.score(ctx, g, g.valid, top_d,
                                             top_i, share=False)
                if traced:
                    jax.block_until_ready(top_d)

            valid_np = np.asarray(g.valid)
            leaves_visited += np.where(active, in_range.sum(1), 0)
            rows_scanned += np.where(active, valid_np.sum(1), 0)

            fr, next_lb_j = _frontier_advance(fr, active_j, v=v)
            rank_next = np.minimum(rank + v, max_rank)
            exhausted = rank_next >= max_rank
            next_lb = np.asarray(next_lb_j).astype(np.float32)
            bsf = np.asarray(top_d[:, k - 1])      # f32, sync point
            stop = refine.stop_mask(next_lb, exhausted, bsf,
                                    eps_mult, rd_sq)
            # attribute each newly stopped lane to ONE condition
            # (priority delta > epsilon > exhausted — a lane can
            # satisfy several at once) and measure the slack at stop:
            # how far past the threshold the predicate fired, in
            # squared-distance units
            newly = active & stop
            if newly.any():
                m_delta = newly & (bsf <= eps_mult * rd_sq)
                m_eps = newly & ~m_delta & (next_lb * eps_mult > bsf)
                m_exh = newly & ~m_delta & ~m_eps
                stop_n["delta"] += int(m_delta.sum())
                stop_n["epsilon"] += int(m_eps.sum())
                stop_n["exhausted"] += int(m_exh.sum())
                if m_delta.any():
                    s = (eps_mult * rd_sq - bsf)[m_delta]
                    slack_sum["delta"] += float(s.sum())
                    slack_n["delta"] += int(m_delta.sum())
                # epsilon slack only over finite next_lb: an inf
                # next_lb means the frontier pool ran dry, not a
                # measurable margin
                m_eps_f = m_eps & np.isfinite(next_lb)
                if m_eps_f.any():
                    s = (next_lb * eps_mult - bsf)[m_eps_f]
                    slack_sum["epsilon"] += float(s.sum())
                    slack_n["epsilon"] += int(m_eps_f.sum())
            active = active & ~stop
            rank = rank_next
            iters += 1
        finally:
            it_span.__exit__(None, None, None)

    with obs.span("ooc.finalize") as f_span:
        top_d, top_i, rerank_bytes = src.finalize(ctx, top_d, top_i, k)
        if traced:
            jax.block_until_ready(top_d)
            # rerank-specific attr name: the ooc.query root owns the
            # subtree's single "bytes_read" (total() must not double-
            # count the rerank bytes folded into it)
            f_span.set(bytes_read_rerank=rerank_bytes)
    result = SearchResult(
        dists=jnp.sqrt(top_d),
        ids=top_i,
        leaves_visited=jnp.asarray(leaves_visited, jnp.int32),
        rows_scanned=jnp.asarray(rows_scanned, jnp.int32),
        lb_computed=jnp.int32(L),
    )
    lv_total = int(leaves_visited.sum())
    # repro: allow[stats-schema] internal transport dict: search_ooc splices these refinement fields straight into the typed OocStats constructor — never a user-facing stats surface
    telem = {
        "iterations": iters,
        "frontier_refills": refills,
        "leaves_visited": lv_total,
        "rows_scanned": int(rows_scanned.sum()),
        "pruning_ratio": 1.0 - lv_total / (b * L) if b * L else 0.0,
        "stop_delta": stop_n["delta"],
        "stop_epsilon": stop_n["epsilon"],
        "stop_exhausted": stop_n["exhausted"],
        "delta_slack": slack_sum["delta"] / slack_n["delta"]
        if slack_n["delta"] else 0.0,
        "eps_slack": slack_sum["epsilon"] / slack_n["epsilon"]
        if slack_n["epsilon"] else 0.0,
    }
    return result, telem, rerank_bytes


def make_source(store: LeafStore, cache: DeviceLeafCache, *,
                prefetch: bool = True, rerank: int = 4):
    """Codec-dispatched LeafSource over an opened store + device
    cache: PQSource for codec="pq", CachedStoreSource otherwise."""
    if store.codec == "pq":
        return PQSource(store, cache, prefetch=prefetch, rerank=rerank)
    return CachedStoreSource(store, cache, prefetch=prefetch)


def search_ooc(
    store: LeafStore,
    queries: jax.Array,  # [B, n]
    k: int,
    g=None,
    *,
    visit_batch: int = 1,
    cache: Optional[DeviceLeafCache] = None,
    cache_leaves: Optional[int] = None,
    prefetch: bool = True,
    share_gathers: bool = False,
    rerank: int = 4,
    frontier: Optional[int] = None,
    prefetch_depth: int = 1,
    fault=None,
    dead: Optional[jax.Array] = None,
    n_override: Optional[int] = None,
    **legacy,
) -> OocResult:
    """k-NN over an on-disk index without device-resident raw data.

    The guarantee is ONE object — ``g=Guarantee(...)`` (constructors
    in core.guarantees); the historical loose ``delta=``/``epsilon=``/
    ``nprobe=`` kwargs still work for one release via the
    APIDeprecationWarning shim (core/spec.py — an error under
    scripts/verify.sh).
    Pass ``cache`` to reuse (and warm) a cache across calls, or
    ``cache_leaves`` to size a fresh one; default is 1/8 of the leaves
    (clamped to at least one iteration's working set).
    ``prefetch=False`` disables speculative scheduling for this call —
    including on a prefetcher already attached to a supplied cache —
    so stats measure pure demand-path reads. ``prefetch_depth`` is the
    frontier-aware lookahead in visit windows: the host frontier hands
    the prefetcher the next ``depth x visit_batch`` leaf ids instead
    of one window (deeper lookahead hides more disk latency on
    sequential visit runs; a lane that stops early wastes at most
    ``depth`` windows of reads).
    ``share_gathers=True`` scores every gathered slot against all query
    lanes (cooperative batching — module docstring). For codec="pq"
    stores, ``rerank``*k candidates per lane are kept through the ADC
    loop and exactly re-ranked against raw rows at the end.
    ``frontier`` tunes the lazy visit-order window width (None ->
    core.refine.default_frontier, widened to cover the prefetch
    lookahead); any width emits the same visit order.
    ``fault`` threads a serving-layer fault context into the host
    loop (checked before every gather and score — docs/FAULT.md);
    injected faults and attempt deadlines propagate out of this call
    as exceptions for the engine's failover loop to catch.
    ``dead``/``n_override`` thread the mutable tier's tombstone mask
    and live joint row count into the host loop (docs/INGEST.md).
    """
    from repro.core.spec import coerce_guarantee

    g = coerce_guarantee(g, legacy, caller="search_ooc")
    if legacy:
        raise TypeError(
            f"search_ooc() got unexpected keyword arguments "
            f"{sorted(legacy)}")
    delta, epsilon, nprobe = g.delta, g.epsilon, g.nprobe
    res = store.resident
    b, n = queries.shape
    L = res.num_leaves
    v = int(visit_batch)
    per_iter = b * v  # worst-case distinct leaves one iteration pins
    depth = max(1, int(prefetch_depth))

    own_prefetcher = None
    if cache is None:
        if cache_leaves is None:
            cache_leaves = max(L // 8, 1)
        cache_leaves = min(max(cache_leaves, per_iter), max(L, 1))
        cache = DeviceLeafCache(store, cache_leaves)
    if prefetch and cache.prefetcher is None:
        # staging bound covers every speculative window in flight
        own_prefetcher = LeafPrefetcher(store, depth=depth + 1)
        cache.prefetcher = own_prefetcher
    pf_used = cache.prefetcher

    if store.codec == "pq" and epsilon == 0.0 and nprobe is None:
        # the stopping predicate compares EXACT leaf lower bounds
        # against the ADC (approximate) kth-best, which can
        # underestimate and prune the true NN's leaf before it is
        # visited; the re-rank only rescores pooled candidates and
        # cannot recover it — so epsilon=0 is NOT exact under pq.
        warnings.warn(
            "codec='pq' cannot honor the exact (epsilon=0) "
            "guarantee: ADC-scored stopping may prune the true "
            "neighbor's leaf. Use epsilon>0 (the epsilon/"
            "delta-epsilon checks hold after the exact re-rank), "
            "nprobe, or a lossless codec.", UserWarning,
            stacklevel=2)

    src = make_source(store, cache, prefetch=prefetch, rerank=rerank)
    guarantee = _guarantee_kind(epsilon=epsilon, delta=delta,
                                nprobe=nprobe)
    root = obs.span("ooc.query", codec=store.codec, lanes=b, k=k,
                    guarantee=guarantee, share_gathers=bool(share_gathers))
    with root:
        try:
            result, telem, rerank_bytes = _host_refine(
                src, queries, k, delta=delta, epsilon=epsilon,
                nprobe=nprobe, visit_batch=v,
                share_gathers=share_gathers, frontier=frontier,
                prefetch_depth=depth, fault=fault, dead=dead,
                n_override=n_override)
        finally:
            if own_prefetcher is not None:
                own_prefetcher.close()
                if cache.prefetcher is own_prefetcher:
                    cache.prefetcher = None

        stats = OocStats(codec=store.codec,
                         share_gathers=bool(share_gathers),
                         prefetch_depth=depth,
                         dataset_bytes=store.dataset_nbytes,
                         bytes_read_rerank=rerank_bytes,
                         **telem)
        for key, val in cache.stats().items():
            setattr(stats, key, val)
        stats.bytes_read += rerank_bytes
        if pf_used is not None:
            if cache.prefetcher is None:  # transient pf detached:
                stats.bytes_read += pf_used.bytes_read  # fold bytes in
            stats.prefetch_bytes_read = pf_used.bytes_read
            stats.prefetch_leaves_read = pf_used.leaves_read
        # the SAME schema instance feeds the span tree (attrs) and the
        # registry — the three views cannot drift
        root.set(bytes_read=stats.bytes_read,
                 bytes_h2d=stats.bytes_h2d,
                 iterations=stats.iterations,
                 frontier_refills=stats.frontier_refills,
                 leaves_visited=stats.leaves_visited,
                 rows_scanned=stats.rows_scanned,
                 pruning_ratio=stats.pruning_ratio,
                 stop_delta=stats.stop_delta,
                 stop_epsilon=stats.stop_epsilon,
                 stop_exhausted=stats.stop_exhausted,
                 delta_slack=stats.delta_slack,
                 eps_slack=stats.eps_slack)
        _publish_ooc_metrics(stats, guarantee)
    return OocResult(result=result, stats=stats)


def _guarantee_kind(*, epsilon: float, delta: float,
                    nprobe: Optional[int]) -> str:
    """Label for the guarantee tier a query ran under (the metric /
    span ``guarantee`` label): ng (fixed rank budget) > delta-epsilon
    (probabilistic early stop armed) > epsilon > exact."""
    if nprobe is not None:
        return "ng"
    if delta < 1.0:
        return "delta-epsilon"
    if epsilon > 0.0:
        return "epsilon"
    return "exact"


def _publish_ooc_metrics(stats: OocStats, guarantee: str) -> None:
    """Fold one query's OocStats into the process-wide registry,
    labeled by codec + guarantee tier."""
    lbl = {"codec": stats.codec or "raw", "guarantee": guarantee}
    reg = obs.REGISTRY
    reg.counter("ooc.queries", **lbl).inc()
    for field in ("bytes_read", "bytes_read_sync", "bytes_h2d",
                  "bytes_read_rerank", "prefetch_bytes_read",
                  "leaves_visited", "rows_scanned", "frontier_refills",
                  "stop_delta", "stop_epsilon", "stop_exhausted"):
        val = stats.get(field, 0)
        if val:
            reg.counter(f"ooc.{field}", **lbl).inc(val)
    reg.histogram("ooc.iterations", **lbl).record(stats.iterations)
    reg.histogram("ooc.pruning_ratio", **lbl).record(stats.pruning_ratio)
