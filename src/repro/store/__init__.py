"""Out-of-core storage tier: the paper's on-disk regime, first class.

The paper's headline finding is that data-series methods win when the
collection does NOT fit in memory; this package makes that a real
workload instead of a hardware-neutral proxy. Design (one screen):

  Residency split.  A built FrozenIndex factors into a SMALL filter
  state (leaf boxes, weights, offsets, ids, distance histogram —
  O(L·D + N) scalars) and a LARGE payload (the [N, n] leaf-contiguous
  raw series). ``FrozenIndex.save(dir)`` persists both; at load time
  ``resident="full"`` reconstitutes the device artifact bit-exactly,
  while ``resident="summaries"`` keeps only the filter state on device
  and opens the payload as an np.memmap (layout.LeafStore). Because the
  rows are leaf-contiguous, one leaf visit is one contiguous read — the
  sequential-I/O unit Hercules/ParIS organize their disk layout around.

  Device leaf cache (cache.DeviceLeafCache).  A fixed slot pool
  [capacity, max_leaf, series_len] on device, host-side leaf->slot map
  with CLOCK (second-chance) eviction, hit/miss/bytes counters, and one
  batched h2d scatter per search iteration for all missing leaves.

  Prefetcher (prefetch.LeafPrefetcher).  A daemon thread stages the
  NEXT iteration's predicted leaves (each lane's next ranks in its
  visit order) into padded host buffers while the device scores the
  current batch — disk latency overlaps compute, double-buffered via a
  bounded staging area; a mispredicted (early-stopped) lane wastes at
  most ``depth`` batches.

  Search (ooc.search_ooc).  The filter stage runs on device over the
  resident summaries EXACTLY as core.search.search; the refinement
  loop moves to the host so it can perform I/O, but it is not a
  mirror — it DRIVES the same shared core (core/refine.py: frontier,
  candidate layout, refine_step, stop predicates) through the
  CachedStoreSource/PQSource LeafSource implementations (ooc.py), so
  the exact / epsilon-approximate / delta-epsilon guarantees of
  Algorithm 2 are preserved by construction (tests/test_store.py
  asserts bit-exact top-k parity with the in-memory path under tiny
  caches; tests/test_refine.py holds every source to the same
  conformance contract).

  Leaf codecs (store format v2, layout.py).  data.bin's payload is
  pluggable: "f32" (native dtype, bit-exact), "bf16" (half the
  bytes-read per leaf; parity is bit-exact vs in-memory search over
  the bfloat16 index), or "pq" (uint8 PQ codes, ~itemsize*n/m x fewer
  bytes; codes are ADC-scored on device via the pq_adc one-hot MXU
  trick and the final top-k is exactly re-ranked against exact.bin so
  the guarantee checks survive the lossy payload). The cache stores
  ENCODED slots; decoding happens in the scoring step.

  Cooperative scoring (ooc.search_ooc(share_gathers=True)).  Every
  iteration's gathered slots are scored against ALL query lanes in one
  MXU matmul, mirroring search_impl's in-memory branch — per-query
  bytes-read drops as the batch grows.

  Out-of-core serving (core/engine.DistributedEngine.query, PR 4).
  Spill-built shards (``build(store=StoreSpec(spill_dir=...,
  codec=..., keep_resident=False))`` or
  ``DistributedEngine.open_spill``) are served directly: a
  host-driven refinement loop per shard over warm per-shard caches,
  merged across shards with ops.topk_merge_unique — bit-exact to the
  HBM-resident shard_map path for lossless codecs. The deadline-aware
  front (serve/batching.Scheduler.run_retrieval) drives it per
  guarantee group; docs/ARCHITECTURE.md diagrams the whole stack.

  Mutable delta tier (delta.py, docs/INGEST.md).  An LSM-style
  in-memory write buffer over the frozen stores: ``engine.insert`` /
  ``engine.delete`` land in a locked memtable, queries snapshot it
  and fold its brute-scored live rows (plus background-compacted
  on-disk segments) into the frozen answer through
  ops.topk_merge_unique — bit-exact against a from-scratch rebuild
  holding the same live rows. Tombstones mask superseded frozen rows
  inside refine_step; the delta guarantee is re-evaluated against the
  joint live row count (core.guarantees.joint_n_total).

Follow-ups tracked in ROADMAP "Open items": zstd-compressed leaves,
NUMA-aware read scheduling, true multi-HOST spill (shards opened on
the host that owns them + a collective merge).
"""

from .cache import DeviceLeafCache
from .delta import (DeltaSnapshot, DeltaTier, FreezeBatch,
                    search_snapshot)
from .layout import (FORMAT_VERSION, LeafStore,
                     StoreFormatDeprecationWarning, load_index,
                     save_index)
from .ooc import (CachedStoreSource, OocResult, PQSource, make_source,
                  search_ooc)
from .prefetch import LeafPrefetcher

__all__ = [
    "CachedStoreSource", "DeltaSnapshot", "DeltaTier",
    "DeviceLeafCache", "FORMAT_VERSION", "FreezeBatch", "LeafStore",
    "LeafPrefetcher", "OocResult", "PQSource",
    "StoreFormatDeprecationWarning", "load_index", "make_source",
    "save_index", "search_ooc", "search_snapshot",
]
