"""Out-of-core storage tier: the paper's on-disk regime, first class.

The paper's headline finding is that data-series methods win when the
collection does NOT fit in memory; this package makes that a real
workload instead of a hardware-neutral proxy. Design (one screen):

  Residency split.  A built FrozenIndex factors into a SMALL filter
  state (leaf boxes, weights, offsets, ids, distance histogram —
  O(L·D + N) scalars) and a LARGE payload (the [N, n] leaf-contiguous
  raw series). ``FrozenIndex.save(dir)`` persists both; at load time
  ``resident="full"`` reconstitutes the device artifact bit-exactly,
  while ``resident="summaries"`` keeps only the filter state on device
  and opens the payload as an np.memmap (layout.LeafStore). Because the
  rows are leaf-contiguous, one leaf visit is one contiguous read — the
  sequential-I/O unit Hercules/ParIS organize their disk layout around.

  Device leaf cache (cache.DeviceLeafCache).  A fixed slot pool
  [capacity, max_leaf, series_len] on device, host-side leaf->slot map
  with CLOCK (second-chance) eviction, hit/miss/bytes counters, and one
  batched h2d scatter per search iteration for all missing leaves.

  Prefetcher (prefetch.LeafPrefetcher).  A daemon thread stages the
  NEXT iteration's predicted leaves (each lane's next ranks in its
  visit order) into padded host buffers while the device scores the
  current batch — disk latency overlaps compute, double-buffered via a
  bounded staging area; a mispredicted (early-stopped) lane wastes at
  most ``depth`` batches.

  Search (ooc.search_ooc).  The filter stage runs on device over the
  resident summaries EXACTLY as core.search.search; the refinement
  loop moves to the host so it can perform I/O, but visits leaves in
  the same order, scores the same candidate layout with the same
  kernels, and evaluates the same f32 stopping predicates — so the
  exact / epsilon-approximate / delta-epsilon guarantees of
  Algorithm 2 are preserved verbatim (tests/test_store.py asserts
  top-k parity with the in-memory path under tiny caches).

  Leaf codecs (store format v2, layout.py).  data.bin's payload is
  pluggable: "f32" (native dtype, bit-exact), "bf16" (half the
  bytes-read per leaf; parity is bit-exact vs in-memory search over
  the bfloat16 index), or "pq" (uint8 PQ codes, ~itemsize*n/m x fewer
  bytes; codes are ADC-scored on device via the pq_adc one-hot MXU
  trick and the final top-k is exactly re-ranked against exact.bin so
  the guarantee checks survive the lossy payload). The cache stores
  ENCODED slots; decoding happens in the scoring step.

  Cooperative scoring (ooc.search_ooc(share_gathers=True)).  Every
  iteration's gathered slots are scored against ALL query lanes in one
  MXU matmul, mirroring search_impl's in-memory branch — per-query
  bytes-read drops as the batch grows.

Follow-ups tracked in ROADMAP "Open items": zstd-compressed leaves,
NUMA-aware read scheduling, and multi-host spill for DistributedEngine
(today each shard spills to its own store directory via
``build(spill_dir=..., codec=...)``).
"""

from .cache import DeviceLeafCache
from .layout import (FORMAT_VERSION, LeafStore,
                     StoreFormatDeprecationWarning, load_index,
                     save_index)
from .ooc import OocResult, search_ooc
from .prefetch import LeafPrefetcher

__all__ = [
    "DeviceLeafCache", "FORMAT_VERSION", "LeafStore", "LeafPrefetcher",
    "OocResult", "StoreFormatDeprecationWarning", "load_index",
    "save_index", "search_ooc",
]
