"""On-disk artifact format (v2) and the LeafStore handle.

A saved index is a directory:

    meta.json      format version + the FrozenIndex static metadata,
                   array shapes, the raw-data dtype, and (v2) the leaf
                   payload ``codec``
    data.bin       [npad, payload_cols] leaf payload rows in the codec's
                   encoding, LEAF-CONTIGUOUS (row i of leaf l lives at
                   offsets[l] + i) — one leaf is one contiguous byte
                   range, so a leaf visit is a single sequential read
    exact.bin      (codec="pq" only) [npad, series_len] raw series in
                   the index dtype, same leaf-contiguous layout; read
                   only for the exact top-k re-rank and resident="full"
    sidecar.npz    box_lo / box_hi / weights / offsets / ids and the
                   distance-histogram edges/cdf (all small, device
                   resident at load time); for codec="pq" also the
                   trained PQ codebook (pq_centroids [m, K, dsub] and
                   pq_rotation [d, d]); since PR 3 also ``row_norms``
                   ([npad] f32 squared norms of the DECODED payload
                   rows) so search_ooc gathers cached norms instead of
                   re-reducing gathered rows every iteration (absent in
                   older sidecars -> recomputed at open, bit-identical
                   via ops.row_sq_norms)

Format v2 — pluggable leaf codecs.  ``codec`` selects the encoding of
``data.bin`` (the bytes the refinement stage streams from disk):

    "f32"   the index's native dtype verbatim (named for the common
            case; a bfloat16-built index stores bfloat16).  v1 bytes,
            bit-exact round trip.
    "bf16"  rows cast to bfloat16 — half the bytes-read per leaf; the
            decoded index is the bfloat16 image of the original, so
            resident="full" returns a bfloat16 FrozenIndex and
            search_ooc is bit-exact to in-memory search over it.
    "pq"    product-quantization codes (K=256, one uint8 per subspace,
            ``pq_m`` codes per row) — ~series_len*itemsize/pq_m x fewer
            bytes-read per leaf.  The codebook is trained at save time
            and persisted in the sidecar; search_ooc ADC-scores codes
            directly on device and exactly re-ranks the final top-k
            against ``exact.bin`` rows so the epsilon/delta-epsilon
            guarantee checks survive the lossy payload.

Version compatibility: v1 artifacts (no ``codec`` key) load read-only
with a :class:`StoreFormatDeprecationWarning` and behave as codec
"f32"; artifacts from a NEWER format version raise ``ValueError``
(scripts/verify.sh turns the deprecation warning into an error so the
repo's own tests never regenerate v1 stores).

``save_index`` persists any FrozenIndex; ``load_index`` either
reconstitutes the full device-resident FrozenIndex (resident="full")
or returns a :class:`LeafStore` (resident="summaries") that keeps only
the filter-stage state on device and opens ``data.bin`` via np.memmap
for the refinement stage to stream.
"""

from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histogram import DistanceHistogram
from repro.core.index import FrozenIndex
from repro.core.summaries.pq import PQCodebook, pq_encode, pq_train
from repro.kernels import ops

FORMAT_VERSION = 2
CODECS = ("f32", "bf16", "pq")
META_NAME = "meta.json"
DATA_NAME = "data.bin"
EXACT_NAME = "exact.bin"
SIDECAR_NAME = "sidecar.npz"
PQ_K = 256  # one uint8 code per subspace


class StoreFormatDeprecationWarning(DeprecationWarning):
    """Raised-as-warning when reading a pre-v2 store artifact."""


def _default_pq_m(series_len: int) -> int:
    for m in (16, 8, 4, 2, 1):
        if series_len % m == 0:
            return m
    return 1


def save_index(
    index: FrozenIndex,
    directory: str,
    *,
    codec: str = "f32",
    pq_m: Optional[int] = None,
    pq_iters: int = 6,
    pq_train_rows: int = 8192,
    pq_key: Optional[jax.Array] = None,
) -> str:
    """Persist ``index`` under ``directory`` (created if missing).

    ``codec`` selects the data.bin leaf payload encoding (module
    docstring); ``pq_*`` tune the codebook trained for codec="pq"
    (``pq_m`` sub-quantizers — must divide series_len, default the
    largest of 16/8/4/2 that does — over at most ``pq_train_rows``
    sampled rows).
    """
    if codec not in CODECS:
        raise ValueError(f"codec must be one of {CODECS}, got {codec!r}")
    os.makedirs(directory, exist_ok=True)
    data = np.asarray(index.data)
    meta = {
        "format_version": FORMAT_VERSION,
        "codec": codec,
        "kind": index.kind,
        "summary": index.summary,
        "n_summary": index.n_summary,
        "max_leaf": index.max_leaf,
        "n_total": index.n_total,
        "series_len": index.series_len,
        "npad": int(data.shape[0]),
        "n_leaves": int(index.num_leaves),
        "n_dims": int(index.box_lo.shape[1]),
        "data_dtype": str(jnp.dtype(index.data.dtype)),
    }
    sidecar = dict(
        box_lo=np.asarray(index.box_lo),
        box_hi=np.asarray(index.box_hi),
        weights=np.asarray(index.weights),
        offsets=np.asarray(index.offsets),
        ids=np.asarray(index.ids),
        hist_edges=np.asarray(index.hist.edges),
        hist_cdf=np.asarray(index.hist.cdf),
    )
    # squared norms of the DECODED payload rows: what the reloaded
    # index (resident="full") and search_ooc's refine gathers both use,
    # so they stay bit-identical to the in-memory search over the same
    # decoded image. f32/pq decode to the index's own rows — reuse the
    # freeze-time cache when present; bf16 decodes to the bfloat16
    # image, whose norms differ from the f32 rows'.
    if codec == "bf16":
        sidecar["row_norms"] = np.asarray(ops.row_sq_norms(
            jnp.asarray(data, jnp.bfloat16)))
    elif index.row_norms is not None:
        sidecar["row_norms"] = np.asarray(index.row_norms)
    else:
        sidecar["row_norms"] = np.asarray(ops.row_sq_norms(
            jnp.asarray(data)))
    if codec == "f32":
        payload = data
    elif codec == "bf16":
        payload = np.asarray(jnp.asarray(data, jnp.bfloat16))
    else:  # pq
        m = _default_pq_m(index.series_len) if pq_m is None else int(pq_m)
        if index.series_len % m:
            raise ValueError(
                f"pq_m={m} must divide series_len={index.series_len}")
        key = pq_key if pq_key is not None else jax.random.PRNGKey(0)
        ids = np.asarray(index.ids)
        rows = np.asarray(data[ids >= 0], np.float32)
        if rows.shape[0] > pq_train_rows:
            sel = np.random.default_rng(0).choice(
                rows.shape[0], pq_train_rows, replace=False)
            rows = rows[sel]
        cb = pq_train(key, jnp.asarray(rows), m, k=PQ_K, iters=pq_iters)
        codes = np.asarray(
            pq_encode(cb, jnp.asarray(data, jnp.float32)), np.uint8)
        payload = codes
        meta["pq_m"] = m
        sidecar["pq_centroids"] = np.asarray(cb.centroids, np.float32)
        sidecar["pq_rotation"] = np.asarray(cb.rotation, np.float32)
        data.tofile(os.path.join(directory, EXACT_NAME))
    meta["payload_dtype"] = str(jnp.dtype(payload.dtype))
    meta["payload_cols"] = int(payload.shape[1])
    payload.tofile(os.path.join(directory, DATA_NAME))
    np.savez(os.path.join(directory, SIDECAR_NAME), **sidecar)
    with open(os.path.join(directory, META_NAME), "w") as f:
        json.dump(meta, f, indent=1)
    return directory


@dataclasses.dataclass
class LeafStore:
    """Out-of-core residency: filter state on device, payload on disk.

    ``resident`` is a FrozenIndex whose ``data`` child is an EMPTY
    [0, series_len] placeholder — everything the filter stage (lower
    bounds, visit order, r_delta) and the id lookup of the refinement
    stage need is device resident; the ENCODED leaf payload is only
    reachable through ``mmap`` (or a DeviceLeafCache layered on top of
    it), and for codec="pq" the raw series additionally through
    ``exact_mmap`` (re-rank reads only).
    """

    directory: str
    resident: FrozenIndex
    mmap: np.memmap          # [npad, payload_cols], leaf-contiguous
    meta: dict
    offsets_h: np.ndarray    # [L+1] int64 host copy for disk reads
    codec: str = "f32"
    exact_mmap: Optional[np.memmap] = None   # pq only: raw rows
    codebook: Optional[PQCodebook] = None    # pq only: device arrays

    @property
    def num_leaves(self) -> int:
        return self.resident.num_leaves

    @property
    def max_leaf(self) -> int:
        return self.resident.max_leaf

    @property
    def series_len(self) -> int:
        return self.resident.series_len

    @property
    def data_dtype(self) -> np.dtype:
        """Dtype of the ENCODED payload rows (what slots/buffers hold)."""
        return self.mmap.dtype

    @property
    def payload_cols(self) -> int:
        """Columns per encoded payload row (= series_len, or pq_m)."""
        return self.mmap.shape[1]

    @property
    def dataset_nbytes(self) -> int:
        """Size of the RAW collection (exact rows in the index dtype),
        NOT the encoded payload — so %-data metrics stay comparable
        across codecs (bf16's payload is half this; pq's far less)."""
        itemsize = np.dtype(jnp.dtype(self.meta["data_dtype"])).itemsize
        return int(self.mmap.shape[0]) * self.series_len * itemsize

    def leaf_size(self, leaf: int) -> int:
        return int(self.offsets_h[leaf + 1] - self.offsets_h[leaf])

    def read_leaf(self, leaf: int, out: np.ndarray = None) -> np.ndarray:
        """One leaf's ENCODED rows, padded to [max_leaf, payload_cols].

        A single contiguous range of ``data.bin`` — the sequential-read
        unit the paper's on-disk evaluation is about. When ``out`` is
        reused across reads, rows past this leaf's size are zeroed so a
        previously resident larger leaf never leaks stale rows.
        """
        lo = int(self.offsets_h[leaf])
        hi = int(self.offsets_h[leaf + 1])
        if out is None:
            out = np.zeros((self.max_leaf, self.payload_cols),
                           self.mmap.dtype)
        else:
            out[hi - lo:] = 0
        out[: hi - lo] = self.mmap[lo:hi]
        return out

    def read_rows_exact(self, positions: np.ndarray) -> np.ndarray:
        """Raw (exact-dtype) rows by padded row position — the pq
        re-rank path. Tiny random reads; callers account the bytes."""
        src = self.exact_mmap if self.exact_mmap is not None else self.mmap
        return np.asarray(src[np.asarray(positions, np.int64)])

    def leaf_nbytes(self, leaf: int) -> int:
        return self.leaf_size(leaf) * self.payload_cols \
            * self.mmap.dtype.itemsize


def load_index(
    directory: str, resident: str = "full"
) -> Union[FrozenIndex, LeafStore]:
    """Open a saved index. resident="full" -> FrozenIndex (bit-exact
    round trip for codec f32/pq, the bfloat16 image for codec bf16);
    resident="summaries" -> LeafStore (payload stays on disk)."""
    with open(os.path.join(directory, META_NAME)) as f:
        meta = json.load(f)
    ver = meta["format_version"]
    if ver > FORMAT_VERSION:
        raise ValueError(
            f"store format {ver} is newer than this reader "
            f"(supports <= {FORMAT_VERSION}); upgrade the code")
    if ver < FORMAT_VERSION:
        warnings.warn(
            f"store format {ver} at {directory!r} is deprecated "
            f"(current: {FORMAT_VERSION}); re-save with save_index to "
            "upgrade", StoreFormatDeprecationWarning, stacklevel=2)
    codec = meta.get("codec", "f32")
    side = np.load(os.path.join(directory, SIDECAR_NAME))
    dtype = jnp.dtype(meta["data_dtype"])
    payload_dtype = jnp.dtype(meta.get("payload_dtype",
                                       meta["data_dtype"]))
    payload_cols = int(meta.get("payload_cols", meta["series_len"]))
    hist = DistanceHistogram(
        edges=jnp.asarray(side["hist_edges"]),
        cdf=jnp.asarray(side["hist_cdf"]),
    )
    statics = dict(
        kind=meta["kind"], summary=meta["summary"],
        n_summary=meta["n_summary"], max_leaf=meta["max_leaf"],
        n_total=meta["n_total"], series_len=meta["series_len"],
    )
    mmap = np.memmap(
        os.path.join(directory, DATA_NAME),
        dtype=np.dtype(payload_dtype),
        mode="r", shape=(meta["npad"], payload_cols),
    )
    exact_mmap = None
    codebook = None
    if codec == "pq":
        exact_mmap = np.memmap(
            os.path.join(directory, EXACT_NAME), dtype=np.dtype(dtype),
            mode="r", shape=(meta["npad"], meta["series_len"]),
        )
        codebook = PQCodebook(
            centroids=jnp.asarray(side["pq_centroids"]),
            rotation=jnp.asarray(side["pq_rotation"]),
        )
    def decoded_norms(chunk_rows: int = 65536):
        """Pre-PR3 sidecars lack row_norms: recompute from the decoded
        rows with the same op the freeze/save paths use. Chunked so a
        summaries-resident open of a legacy store never materializes
        the whole payload on device (row-wise sums are independent of
        the chunking, so the result stays bit-identical)."""
        src = exact_mmap if codec == "pq" else mmap
        out = np.empty(src.shape[0], np.float32)
        for lo in range(0, src.shape[0], chunk_rows):
            hi = min(lo + chunk_rows, src.shape[0])
            out[lo:hi] = np.asarray(
                ops.row_sq_norms(jnp.asarray(np.asarray(src[lo:hi]))))
        return jnp.asarray(out)

    row_norms = (jnp.asarray(side["row_norms"])
                 if "row_norms" in side else decoded_norms())
    if resident == "full":
        if codec == "pq":
            full_rows = jnp.asarray(np.asarray(exact_mmap), dtype)
        elif codec == "bf16":
            full_rows = jnp.asarray(np.asarray(mmap))  # bfloat16 image
        else:
            full_rows = jnp.asarray(np.asarray(mmap), dtype)
        return FrozenIndex(
            box_lo=jnp.asarray(side["box_lo"]),
            box_hi=jnp.asarray(side["box_hi"]),
            weights=jnp.asarray(side["weights"]),
            offsets=jnp.asarray(side["offsets"]),
            data=full_rows,
            ids=jnp.asarray(side["ids"]),
            hist=hist,
            row_norms=row_norms,
            **statics,
        )
    if resident != "summaries":
        raise ValueError("resident must be 'full' or 'summaries', "
                         f"got {resident!r}")
    placeholder = jnp.zeros((0, meta["series_len"]), dtype)
    res = FrozenIndex(
        box_lo=jnp.asarray(side["box_lo"]),
        box_hi=jnp.asarray(side["box_hi"]),
        weights=jnp.asarray(side["weights"]),
        offsets=jnp.asarray(side["offsets"]),
        data=placeholder,
        ids=jnp.asarray(side["ids"]),
        hist=hist,
        row_norms=row_norms,
        **statics,
    )
    return LeafStore(
        directory=directory,
        resident=res,
        mmap=mmap,
        meta=meta,
        offsets_h=np.asarray(side["offsets"], np.int64),
        codec=codec,
        exact_mmap=exact_mmap,
        codebook=codebook,
    )
