"""On-disk artifact format and the LeafStore handle.

A saved index is a directory:

    meta.json      format version + the FrozenIndex static metadata,
                   array shapes and the raw-data dtype
    data.bin       [npad, series_len] raw series in the index dtype,
                   LEAF-CONTIGUOUS (row i of leaf l lives at
                   offsets[l] + i) — one leaf is one contiguous byte
                   range, so a leaf visit is a single sequential read
    sidecar.npz    box_lo / box_hi / weights / offsets / ids and the
                   distance-histogram edges/cdf (all small, device
                   resident at load time)

``save_index`` persists any FrozenIndex bit-exactly; ``load_index``
either reconstitutes the full device-resident FrozenIndex
(resident="full") or returns a :class:`LeafStore` (resident="summaries")
that keeps only the filter-stage state on device and opens ``data.bin``
via np.memmap for the refinement stage to stream.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Union

import jax.numpy as jnp
import numpy as np

from repro.core.histogram import DistanceHistogram
from repro.core.index import FrozenIndex

FORMAT_VERSION = 1
META_NAME = "meta.json"
DATA_NAME = "data.bin"
SIDECAR_NAME = "sidecar.npz"


def save_index(index: FrozenIndex, directory: str) -> str:
    """Persist ``index`` under ``directory`` (created if missing)."""
    os.makedirs(directory, exist_ok=True)
    data = np.asarray(index.data)
    meta = {
        "format_version": FORMAT_VERSION,
        "kind": index.kind,
        "summary": index.summary,
        "n_summary": index.n_summary,
        "max_leaf": index.max_leaf,
        "n_total": index.n_total,
        "series_len": index.series_len,
        "npad": int(data.shape[0]),
        "n_leaves": int(index.num_leaves),
        "n_dims": int(index.box_lo.shape[1]),
        "data_dtype": str(jnp.dtype(index.data.dtype)),
    }
    data.tofile(os.path.join(directory, DATA_NAME))
    np.savez(
        os.path.join(directory, SIDECAR_NAME),
        box_lo=np.asarray(index.box_lo),
        box_hi=np.asarray(index.box_hi),
        weights=np.asarray(index.weights),
        offsets=np.asarray(index.offsets),
        ids=np.asarray(index.ids),
        hist_edges=np.asarray(index.hist.edges),
        hist_cdf=np.asarray(index.hist.cdf),
    )
    with open(os.path.join(directory, META_NAME), "w") as f:
        json.dump(meta, f, indent=1)
    return directory


@dataclasses.dataclass
class LeafStore:
    """Out-of-core residency: filter state on device, raw data on disk.

    ``resident`` is a FrozenIndex whose ``data`` child is an EMPTY
    [0, series_len] placeholder — everything the filter stage (lower
    bounds, visit order, r_delta) and the id lookup of the refinement
    stage need is device resident; the raw series are only reachable
    through ``mmap`` (or a DeviceLeafCache layered on top of it).
    """

    directory: str
    resident: FrozenIndex
    mmap: np.memmap          # [npad, series_len], leaf-contiguous
    meta: dict
    offsets_h: np.ndarray    # [L+1] int64 host copy for disk reads

    @property
    def num_leaves(self) -> int:
        return self.resident.num_leaves

    @property
    def max_leaf(self) -> int:
        return self.resident.max_leaf

    @property
    def series_len(self) -> int:
        return self.resident.series_len

    @property
    def data_dtype(self) -> np.dtype:
        return self.mmap.dtype

    def leaf_size(self, leaf: int) -> int:
        return int(self.offsets_h[leaf + 1] - self.offsets_h[leaf])

    def read_leaf(self, leaf: int, out: np.ndarray = None) -> np.ndarray:
        """One leaf's rows, padded to [max_leaf, series_len].

        A single contiguous range of ``data.bin`` — the sequential-read
        unit the paper's on-disk evaluation is about.
        """
        lo = int(self.offsets_h[leaf])
        hi = int(self.offsets_h[leaf + 1])
        if out is None:
            out = np.zeros((self.max_leaf, self.series_len),
                           self.mmap.dtype)
        else:
            out[hi - lo:] = 0
        out[: hi - lo] = self.mmap[lo:hi]
        return out

    def leaf_nbytes(self, leaf: int) -> int:
        return self.leaf_size(leaf) * self.series_len \
            * self.mmap.dtype.itemsize


def load_index(
    directory: str, resident: str = "full"
) -> Union[FrozenIndex, LeafStore]:
    """Open a saved index. resident="full" -> FrozenIndex (bit-exact
    round trip, everything on device); resident="summaries" ->
    LeafStore (raw data stays on disk)."""
    with open(os.path.join(directory, META_NAME)) as f:
        meta = json.load(f)
    if meta["format_version"] != FORMAT_VERSION:
        raise ValueError(
            f"store format {meta['format_version']} != {FORMAT_VERSION}")
    side = np.load(os.path.join(directory, SIDECAR_NAME))
    dtype = jnp.dtype(meta["data_dtype"])
    hist = DistanceHistogram(
        edges=jnp.asarray(side["hist_edges"]),
        cdf=jnp.asarray(side["hist_cdf"]),
    )
    statics = dict(
        kind=meta["kind"], summary=meta["summary"],
        n_summary=meta["n_summary"], max_leaf=meta["max_leaf"],
        n_total=meta["n_total"], series_len=meta["series_len"],
    )
    mmap = np.memmap(
        os.path.join(directory, DATA_NAME), dtype=np.dtype(dtype),
        mode="r", shape=(meta["npad"], meta["series_len"]),
    )
    if resident == "full":
        return FrozenIndex(
            box_lo=jnp.asarray(side["box_lo"]),
            box_hi=jnp.asarray(side["box_hi"]),
            weights=jnp.asarray(side["weights"]),
            offsets=jnp.asarray(side["offsets"]),
            data=jnp.asarray(np.asarray(mmap), dtype),
            ids=jnp.asarray(side["ids"]),
            hist=hist,
            **statics,
        )
    if resident != "summaries":
        raise ValueError(f"resident must be 'full' or 'summaries', "
                         f"got {resident!r}")
    placeholder = jnp.zeros((0, meta["series_len"]), dtype)
    res = FrozenIndex(
        box_lo=jnp.asarray(side["box_lo"]),
        box_hi=jnp.asarray(side["box_hi"]),
        weights=jnp.asarray(side["weights"]),
        offsets=jnp.asarray(side["offsets"]),
        data=placeholder,
        ids=jnp.asarray(side["ids"]),
        hist=hist,
        **statics,
    )
    return LeafStore(
        directory=directory,
        resident=res,
        mmap=mmap,
        meta=meta,
        offsets_h=np.asarray(side["offsets"], np.int64),
    )
