"""Async double-buffered host-side leaf prefetcher.

The out-of-core search loop knows, while the device is scoring
iteration t's leaves, exactly which leaves iteration t+1 will want
(each query's next ranks in its lb visit order, assuming it stays
active). ``schedule()`` hands that set to a daemon thread which reads
the leaves from the memmap into padded host buffers; ``take()`` pops a
staged buffer on the demand path. The staging area is bounded to
``depth`` scheduled batches ("double-buffered" at the default depth=2),
so a query that stops early wastes at most ``depth`` batches of reads.

The prefetcher only READS (memmap -> host buffer). The device upload
stays in DeviceLeafCache._fill, which already batches one scatter per
iteration; overlapping h2d as well would need per-slot donation and
buys little on top of overlapping the disk latency, which dominates.
"""

from __future__ import annotations

import collections
import itertools
import threading
import warnings
from typing import Optional, Sequence

import numpy as np

from repro.obs import REGISTRY, now

from .layout import LeafStore

_prefetcher_ids = itertools.count()


class LeafPrefetcher:
    def __init__(self, store: LeafStore, depth: int = 2,
                 name: Optional[str] = None):
        self.store = store
        self.depth = int(depth)
        self.name = name or f"prefetch{next(_prefetcher_ids)}"
        # every shared field below is annotated guarded_by and the
        # annotation is CHECKED: python -m repro.analysis enforces
        # that all access outside __init__ sits in `with self._lock:`
        # (docs/ANALYSIS.md — this class is where the old "mutated
        # ONLY under self._lock" comment lived unchecked)
        self._lock = threading.Condition()
        self._queue: collections.deque = \
            collections.deque()                   # guarded_by: _lock
        self._staged: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()             # guarded_by: _lock
        self._inflight: set = set()               # guarded_by: _lock
        self._wanted: set = set()                 # guarded_by: _lock
        self._batches_staged: collections.deque = \
            collections.deque()                   # guarded_by: _lock
        self._stop = False                        # guarded_by: _lock
        self._dead = False                        # guarded_by: _lock
        # leaf mid-read right now:
        self._reading: Optional[int] = None       # guarded_by: _lock
        # counters: mutated ONLY under self._lock (the reader thread
        # races reset_counters otherwise — a straggler cold-pass read
        # landing after the reset would pollute warm-run stats); the
        # epoch stamps each read with its measurement window so even a
        # read that outlives reset_counters' quiesce timeout cannot
        # leak its bytes into the next window. Since PR 6 the counters
        # are registry-backed (store.prefetch.* in repro.obs.REGISTRY):
        # reset_counters() starts a window via marks, the registry
        # keeps the process-lifetime totals.
        self._epoch = 0                           # guarded_by: _lock
        lbl = {"prefetch": self.name}
        self._c_bytes_read = REGISTRY.counter(
            "store.prefetch.bytes_read", **lbl)
        self._c_leaves_read = REGISTRY.counter(
            "store.prefetch.leaves_read", **lbl)
        # deadline expiries (take/reset_counters) and close() leaks
        # are SURFACED, not swallowed: a silently slow disk shows up
        # here first (docs/OBSERVABILITY.md)
        self._c_quiesce_take = REGISTRY.counter(
            "store.prefetch.quiesce_timeout", site="take", **lbl)
        self._c_quiesce_reset = REGISTRY.counter(
            "store.prefetch.quiesce_timeout", site="reset", **lbl)
        self._c_close_leaked = REGISTRY.counter(
            "store.prefetch.close_leaked", **lbl)
        self._c_bytes_read.mark()
        self._c_leaves_read.mark()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    @property
    def bytes_read(self) -> int:
        """Disk bytes read this window (includes speculative reads)."""
        return self._c_bytes_read.since_mark

    @property
    def leaves_read(self) -> int:
        return self._c_leaves_read.since_mark

    # ------------------------------------------------------------------
    def schedule(self, leaves: Sequence[int]) -> None:
        """Stage a predicted future leaf batch (speculative). Callers
        with frontier lookahead schedule several batches per iteration
        (nearest window first — it is read first)."""
        batch = list(dict.fromkeys(int(x) for x in leaves))
        with self._lock:
            # bound the staging area: drop the oldest whole batch(es)
            while len(self._batches_staged) >= self.depth:
                self._batches_staged.popleft()
            todo = [lf for lf in batch
                    if lf not in self._staged and lf not in self._inflight]
            self._batches_staged.append(batch)
            # keep every structure bounded to the LIVE batches: a leaf
            # no longer in any tracked batch is dropped from the
            # staging dict and the read queue and (if mid-read) its
            # completion is discarded. Membership is tested against
            # the UNION of live batches, never per dropped batch —
            # overlapping windows (the frontier-lookahead regime
            # re-schedules next iteration's window every iteration)
            # must not have their staged buffers destroyed by an old
            # batch's eviction, which would force a duplicate read.
            self._wanted = set()
            for bt in self._batches_staged:
                self._wanted.update(bt)
            for lf in [s for s in self._staged if s not in self._wanted]:
                del self._staged[lf]
            self._queue = collections.deque(
                lf for lf in self._queue if lf in self._wanted)
            self._inflight &= self._wanted
            self._inflight.update(todo)
            self._queue.extend(todo)
            self._lock.notify_all()

    def take(self, leaf: int,
             timeout: float = 10.0) -> Optional[np.ndarray]:
        """Pop a staged leaf buffer; None if this leaf was never
        scheduled (or was dropped / the thread died).

        A leaf still queued or in flight is WAITED for: the thread is
        reading it right now (or is about to), so waiting costs at most
        the tail of one batch of reads, whereas returning None would
        make the caller issue a duplicate synchronous read of bytes the
        prefetcher already paid for. The prefetcher remains a pure
        overlap optimization, never a correctness dependency — every
        None falls back to a sync read in the cache.

        Stop/dead Nones are expected teardown; a DEADLINE expiry means
        the disk is slower than the timeout and the miss silently
        doubles the read — so expiries are surfaced
        (``store.prefetch.quiesce_timeout{site=take}`` + a warning)
        instead of vanishing into the fallback.
        """
        leaf = int(leaf)
        deadline = now() + timeout
        with self._lock:
            while True:
                if leaf in self._staged:
                    return self._staged.pop(leaf)
                if leaf not in self._inflight and leaf not in self._queue:
                    return None
                if self._stop or self._dead:
                    return None
                remaining = deadline - now()
                if remaining <= 0:
                    self._c_quiesce_take.inc()
                    warnings.warn(
                        f"prefetcher {self.name}: take({leaf}) gave "
                        f"up after {timeout:.1f}s with the read "
                        "still pending — the caller falls back to a "
                        "duplicate sync read (slow disk?)",
                        RuntimeWarning, stacklevel=2)
                    return None
                self._lock.wait(remaining)

    def reset_counters(self, timeout: float = 10.0) -> None:
        """Zero the read counters for a fresh measurement window.

        Quiesces first: queued (not yet started) speculative reads are
        dropped, and an in-flight read is WAITED for — so no byte read
        on behalf of the previous window can land after the zeroing.
        Even if the wait times out (pathologically slow disk), the
        epoch bump makes the straggler's completion drop its counter
        update, so the new window still starts clean.
        """
        deadline = now() + timeout
        with self._lock:
            for lf in self._queue:
                self._inflight.discard(lf)
            self._queue.clear()
            while self._reading is not None and not self._dead:
                remaining = deadline - now()
                if remaining <= 0:
                    # the epoch bump below still keeps the window
                    # clean, but a quiesce that cannot finish inside
                    # the timeout is a slow-disk signal the operator
                    # must see, not an implementation detail
                    self._c_quiesce_reset.inc()
                    warnings.warn(
                        f"prefetcher {self.name}: reset_counters "
                        f"quiesce timed out after {timeout:.1f}s "
                        f"with leaf {self._reading} mid-read; the "
                        "epoch guard keeps the new window clean",
                        RuntimeWarning, stacklevel=2)
                    break
                self._lock.wait(remaining)
            self._epoch += 1
            self._c_bytes_read.mark()
            self._c_leaves_read.mark()

    def close(self, timeout: float = 5.0) -> None:
        """Stop the reader thread and join it. A thread that outlives
        the join timeout (wedged in a read syscall) is REPORTED —
        ``store.prefetch.close_leaked`` counter + warning — instead of
        leaking silently; it is a daemon thread, so the report is
        about the wedged I/O, not process shutdown."""
        with self._lock:
            self._stop = True
            self._lock.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            self._c_close_leaked.inc()
            warnings.warn(
                f"prefetcher {self.name}: reader thread still alive "
                f"{timeout:.1f}s after close() — wedged in a read? "
                "(daemon thread; it cannot block exit, but its memmap "
                "stays open)", RuntimeWarning, stacklevel=2)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    def _run(self) -> None:
        try:
            while True:
                with self._lock:
                    while not self._queue and not self._stop:
                        self._lock.wait()
                    if self._stop:
                        return
                    leaf = self._queue.popleft()
                    self._reading = leaf
                    epoch = self._epoch
                buf = self.store.read_leaf(leaf)
                nbytes = self.store.leaf_nbytes(leaf)
                with self._lock:
                    self._inflight.discard(leaf)
                    self._reading = None
                    if not self._stop and leaf in self._wanted:
                        self._staged[leaf] = buf
                    if epoch == self._epoch:  # not reset mid-read
                        self._c_bytes_read.inc(nbytes)
                        self._c_leaves_read.inc()
                    self._lock.notify_all()
        except Exception:  # I/O failure: unblock waiters, go demand-only
            with self._lock:
                self._dead = True
                self._reading = None
                self._inflight.clear()
                self._queue.clear()
                self._lock.notify_all()
