"""Version-spanning JAX API shims.

The repo targets the installed jax (0.4.x) while staying forward
compatible with the renamed/moved APIs in newer releases. Keep every
cross-version guard here so call sites stay clean.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map (>=0.5, `check_vma`) or the 0.4.x
    jax.experimental.shard_map (`check_rep`)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=check)
        except TypeError:  # intermediate releases: check_rep spelling
            return sm(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as legacy

    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


def cost_analysis(compiled) -> dict:
    """Compiled.cost_analysis() as a dict: 0.4.x returns a one-element
    list of dicts (per program), newer JAX returns the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
