"""Model substrate package.

Submodules are intentionally NOT imported eagerly: configs.base imports
repro.models.moe/ssm for their config NamedTuples, while model modules
import repro.configs.base — lazy access keeps the import graph acyclic.
"""

__all__ = [
    "attention", "encdec", "layers", "model", "moe", "params", "ssm",
    "transformer",
]
