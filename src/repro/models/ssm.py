"""Mamba-2 SSD (state-space duality) layer, TPU-native chunked formulation.

Implements the selective state-space model of arXiv:2405.21060 with the
chunked SSD algorithm: within-chunk terms are attention-like batched
matmuls (MXU-friendly), across-chunk terms are a short `lax.scan` over the
per-chunk state recurrence. A naive O(S) sequential reference
(`ssd_reference`) backs the unit/property tests, and `ssm_decode_step`
carries the O(1) recurrent state for autoregressive serving (this is what
makes the `long_500k` shape tractable for SSM/hybrid architectures).

Parameterization follows mamba2: per-head scalar decay A, grouped B/C of
state dim N, depthwise short conv on (x, B, C), gated RMSNorm before the
output projection. Projections are split per-section (z/x/B/C/dt) so the
'ssm_inner' logical axis (heads × head_dim) tensor-shards cleanly.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import rmsnorm_apply, rmsnorm_specs
from .params import ParamSpec
from .sharding_utils import constrain, unshard_fsdp


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_specs(cfg: SSMConfig, dtype) -> Dict[str, Any]:
    d, di, n, g, h = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_groups,
                      cfg.n_heads)
    return {
        "wz": ParamSpec((d, di), ("fsdp", "ssm_inner"), dtype=dtype,
                        init="scaled", fan_in_axes=(0,)),
        "wx": ParamSpec((d, di), ("fsdp", "ssm_inner"), dtype=dtype,
                        init="scaled", fan_in_axes=(0,)),
        "wB": ParamSpec((d, g * n), ("fsdp", None), dtype=dtype,
                        init="scaled", fan_in_axes=(0,)),
        "wC": ParamSpec((d, g * n), ("fsdp", None), dtype=dtype,
                        init="scaled", fan_in_axes=(0,)),
        "wdt": ParamSpec((d, h), ("fsdp", None), dtype=dtype,
                         init="scaled", fan_in_axes=(0,)),
        "conv_x": ParamSpec((cfg.d_conv, di), ("conv", "ssm_inner"),
                            dtype=dtype, init="scaled", fan_in_axes=(0,)),
        "conv_B": ParamSpec((cfg.d_conv, g * n), ("conv", None), dtype=dtype,
                            init="scaled", fan_in_axes=(0,)),
        "conv_C": ParamSpec((cfg.d_conv, g * n), ("conv", None), dtype=dtype,
                            init="scaled", fan_in_axes=(0,)),
        "dt_bias": ParamSpec((h,), (None,), dtype=jnp.float32,
                             init="constant", scale=0.0),
        "A_log": ParamSpec((h,), (None,), dtype=jnp.float32, init="zeros"),
        "D": ParamSpec((h,), (None,), dtype=jnp.float32, init="ones"),
        "norm": rmsnorm_specs(di, jnp.float32),
        "wo": ParamSpec((di, d), ("ssm_inner", "fsdp"), dtype=dtype,
                        init="scaled", fan_in_axes=(0,)),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv. x [B,S,C], kernel [W,C]."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
    return out


def _project(params, u: jax.Array, cfg: SSMConfig):
    dtype = u.dtype
    wz = unshard_fsdp(params["wz"], "fsdp", "ssm_inner").astype(dtype)
    wx = unshard_fsdp(params["wx"], "fsdp", "ssm_inner").astype(dtype)
    wB = unshard_fsdp(params["wB"], "fsdp", None).astype(dtype)
    wC = unshard_fsdp(params["wC"], "fsdp", None).astype(dtype)
    wdt = unshard_fsdp(params["wdt"], "fsdp", None).astype(dtype)
    z = jnp.einsum("bsd,de->bse", u, wz)
    x = jnp.einsum("bsd,de->bse", u, wx)
    bb = jnp.einsum("bsd,de->bse", u, wB)
    cc = jnp.einsum("bsd,de->bse", u, wC)
    dt = jnp.einsum("bsd,dh->bsh", u, wdt)
    return z, x, bb, cc, dt


def _activate(params, x, bb, cc, dt, cfg: SSMConfig):
    b, s, _ = x.shape
    x = jax.nn.silu(x)
    bb = jax.nn.silu(bb)
    cc = jax.nn.silu(cc)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max * 100.0)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H], negative
    xh = x.reshape(b, s, cfg.n_heads, cfg.head_dim)
    bg = bb.reshape(b, s, cfg.n_groups, cfg.d_state)
    cg = cc.reshape(b, s, cfg.n_groups, cfg.d_state)
    # broadcast groups over heads
    rep = cfg.n_heads // cfg.n_groups
    bh = jnp.repeat(bg, rep, axis=2)  # [B,S,H,N]
    ch = jnp.repeat(cg, rep, axis=2)
    # pin (batch, heads) so GSPMD keeps the chunked-SSD einsums local
    xh = constrain(xh, "batch", None, "ssm_inner", None)
    bh = constrain(bh, "batch", None, "ssm_inner", None)
    ch = constrain(ch, "batch", None, "ssm_inner", None)
    dt = constrain(dt, "batch", None, "ssm_inner")
    return xh, bh, ch, dt, a


def ssd_chunked(
    xh: jax.Array,  # [B,S,H,P] f32-castable
    bh: jax.Array,  # [B,S,H,N]
    ch: jax.Array,  # [B,S,H,N]
    dt: jax.Array,  # [B,S,H] f32
    a: jax.Array,   # [H] f32 (negative)
    chunk: int,
    h0: Optional[jax.Array] = None,  # [B,H,N,P] initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y [B,S,H,P], h_final [B,H,N,P])."""
    b, s, h, p = xh.shape
    n = bh.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    def rs(t):  # [B,S,...] -> [B,nc,chunk,...]
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xc, bc, cc_, dtc = rs(xh.astype(f32)), rs(bh.astype(f32)), \
        rs(ch.astype(f32)), rs(dt)
    da = dtc * a[None, None, None, :]  # [B,nc,Q,H]
    cum = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk
    total = cum[:, :, -1, :]  # [B,nc,H]

    # ---- intra-chunk (attention-like) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    q = chunk
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bnihd,bnjhd->bnijh", cc_, bc)  # C_i . B_j
    att = scores * decay * dtc[:, :, None, :, :]  # weight by dt_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att, xc)

    # ---- chunk states ----
    # S_c = sum_j exp(total - cum_j) * dt_j * B_j (x) x_j  -> [B,nc,H,N,P]
    w = jnp.exp(total[:, :, None, :] - cum) * dtc  # [B,nc,Q,H]
    states = jnp.einsum("bnjh,bnjhd,bnjhp->bnhdp", w, bc, xc)

    # ---- inter-chunk recurrence over nc (sequential scan) ----
    chunk_decay = jnp.exp(total)  # [B,nc,H]
    init = (jnp.zeros((b, h, n, p), f32) if h0 is None
            else h0.astype(f32))

    def step(hprev, inp):
        dcy, st = inp  # [B,H], [B,H,N,P]
        hnew = hprev * dcy[:, :, None, None] + st
        return hnew, hprev  # emit state *entering* the chunk

    hfin, h_enter = jax.lax.scan(
        step, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # [B,nc,H,N,P]

    # ---- inter-chunk contribution: C_i . (exp(cum_i) * h_enter) ----
    y_inter = jnp.einsum(
        "bnihd,bnhdp->bnihp", cc_ * jnp.exp(cum)[..., None], h_enter
    )

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, hfin


def ssd_reference(xh, bh, ch, dt, a, h0=None):
    """Naive sequential scan oracle (tests only)."""
    b, s, h, p = xh.shape
    n = bh.shape[-1]
    f32 = jnp.float32
    hst = jnp.zeros((b, h, n, p), f32) if h0 is None else h0.astype(f32)
    ys = []
    for t in range(s):
        dct = jnp.exp(dt[:, t, :] * a[None, :])  # [B,H]
        upd = jnp.einsum("bh,bhd,bhp->bhdp", dt[:, t, :].astype(f32),
                         bh[:, t].astype(f32), xh[:, t].astype(f32))
        hst = hst * dct[:, :, None, None] + upd
        y = jnp.einsum("bhd,bhdp->bhp", ch[:, t].astype(f32), hst)
        ys.append(y)
    return jnp.stack(ys, axis=1), hst


def ssm_apply(
    params: Dict[str, Any], u: jax.Array, cfg: SSMConfig,
    return_cache: bool = False,
):
    """Full-sequence SSD forward (train / prefill). u: [B,S,d_model].

    With ``return_cache`` also returns the decode cache (conv tails + final
    SSM state) so prefill can hand off to ``ssm_decode_step``.
    """
    dtype = u.dtype
    b, s = u.shape[:2]
    z, x_pre, bb_pre, cc_pre, dt = _project(params, u, cfg)
    x = _causal_conv(x_pre, params["conv_x"].astype(dtype))
    bb = _causal_conv(bb_pre, params["conv_B"].astype(dtype))
    cc = _causal_conv(cc_pre, params["conv_C"].astype(dtype))
    xh, bh, ch, dtf, a = _activate(params, x, bb, cc, dt, cfg)
    chunk = min(cfg.chunk, s)
    if s % chunk != 0:  # fall back to a divisor for odd smoke shapes
        chunk = 1
        for c in range(min(cfg.chunk, s), 0, -1):
            if s % c == 0:
                chunk = c
                break
    y, hfin = ssd_chunked(xh, bh, ch, dtf, a, chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(b, s, cfg.d_inner).astype(dtype)
    y = rmsnorm_apply(params["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["wo"].astype(dtype))
    if not return_cache:
        return out

    def tail(t):  # last d_conv-1 *pre-conv* inputs
        w = cfg.d_conv - 1
        tp = jnp.pad(t, ((0, 0), (w, 0), (0, 0)))
        return tp[:, tp.shape[1] - w:, :]

    cache = {
        "conv_x": tail(x_pre),
        "conv_B": tail(bb_pre),
        "conv_C": tail(cc_pre),
        "h": hfin.astype(dtype),
    }
    return out, cache


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent state
# ---------------------------------------------------------------------------

def ssm_cache_shape(cfg: SSMConfig, batch: int):
    conv_dim_x = cfg.d_inner
    gn = cfg.n_groups * cfg.d_state
    return {
        "conv_x": (batch, cfg.d_conv - 1, conv_dim_x),
        "conv_B": (batch, cfg.d_conv - 1, gn),
        "conv_C": (batch, cfg.d_conv - 1, gn),
        "h": (batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
    }


def _conv_step(state, xnew, kernel):
    """state [B,W-1,C], xnew [B,C] -> (new_state, y [B,C])."""
    full = jnp.concatenate([state, xnew[:, None, :]], axis=1)  # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", full, kernel)
    return full[:, 1:, :], y


def ssm_decode_step(
    params: Dict[str, Any],
    u: jax.Array,  # [B, 1, d_model]
    cache: Dict[str, jax.Array],
    cfg: SSMConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    dtype = u.dtype
    b = u.shape[0]
    z, x, bb, cc, dt = _project(params, u, cfg)
    z, x, bb, cc, dt = (t[:, 0] for t in (z, x, bb, cc, dt))

    conv_x, x = _conv_step(cache["conv_x"], x, params["conv_x"].astype(dtype))
    conv_B, bb = _conv_step(cache["conv_B"], bb,
                            params["conv_B"].astype(dtype))
    conv_C, cc = _conv_step(cache["conv_C"], cc,
                            params["conv_C"].astype(dtype))

    x = jax.nn.silu(x)
    bb = jax.nn.silu(bb)
    cc = jax.nn.silu(cc)
    dtf = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"][None, :]
    )
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = x.reshape(b, cfg.n_heads, cfg.head_dim).astype(jnp.float32)
    rep = cfg.n_heads // cfg.n_groups
    bh = jnp.repeat(bb.reshape(b, cfg.n_groups, cfg.d_state), rep, axis=1)
    ch = jnp.repeat(cc.reshape(b, cfg.n_groups, cfg.d_state), rep, axis=1)

    h = cache["h"].astype(jnp.float32)  # [B,H,N,P]
    decay = jnp.exp(dtf * a[None, :])  # [B,H]
    upd = jnp.einsum("bh,bhd,bhp->bhdp", dtf, bh.astype(jnp.float32), xh)
    h = h * decay[:, :, None, None] + upd
    y = jnp.einsum("bhd,bhdp->bhp", ch.astype(jnp.float32), h)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, cfg.d_inner).astype(dtype)
    y = rmsnorm_apply(params["norm"], y * jax.nn.silu(z))
    wo = unshard_fsdp(params["wo"], "ssm_inner", "fsdp").astype(dtype)
    out = jnp.einsum("be,ed->bd", y, wo)
    new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "h": h.astype(cache["h"].dtype)}
    return out[:, None, :], new_cache
