"""Grouped-query attention with local/global variants, softcap, KV cache.

Three execution paths, all numerically equivalent where they overlap:

* ``_attend_dense``     — single-block masked attention (short sequences,
                          encoder / cross attention, smoke tests).
* ``_attend_blockwise`` — query-chunked online-softmax attention
                          (flash-style, pure JAX): O(S·chunk) live memory
                          for global-causal, O(S·(window+chunk)) *compute*
                          for sliding-window layers via dynamic KV slices.
* ``decode_attend``     — single-token query against a KV cache.

GQA never materializes repeated KV heads: scores are computed with the
grouped einsum ``[B,Sq,Kv,G,D] x [B,Sk,Kv,D] -> [B,Kv,G,Sq,Sk]``.

Tensor-parallel note: Q heads shard over 'model'; when kv_heads does not
divide the model axis (e.g. 8 kv heads on a 16-way axis) the param resolver
shards K/V over head_dim instead — the score einsum then contracts over a
sharded dim and GSPMD inserts the psum (the standard MQA/GQA decode TP
strategy).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import softcap
from .params import ParamSpec
from .sharding_utils import constrain, unshard_fsdp

NEG_INF = -2.3819763e38  # large negative, safe in bf16 after cast


class AttnConfig(NamedTuple):
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    logit_cap: Optional[float] = None
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    rope_theta: float = 10000.0
    use_rope: bool = True
    chunk_q: int = 512  # blockwise query chunk
    dense_threshold: int = 2048  # below this seq len use the dense path


def attn_specs(cfg: AttnConfig, dtype) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, h, hd), ("fsdp", "heads", "head_dim"),
                        dtype=dtype, init="scaled", fan_in_axes=(0,)),
        "wk": ParamSpec((d, kv, hd), ("fsdp", "kv_heads", "head_dim"),
                        dtype=dtype, init="scaled", fan_in_axes=(0,)),
        "wv": ParamSpec((d, kv, hd), ("fsdp", "kv_heads", "head_dim"),
                        dtype=dtype, init="scaled", fan_in_axes=(0,)),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "fsdp"),
                        dtype=dtype, init="scaled", fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), dtype=dtype,
                                init="zeros")
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                dtype=dtype, init="zeros")
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                dtype=dtype, init="zeros")
    return specs


def _project_qkv(params, x, cfg: AttnConfig, positions):
    from .sharding_utils import unshard_fsdp

    dtype = x.dtype
    wq = unshard_fsdp(params["wq"], "fsdp", "heads", "head_dim")
    wk = unshard_fsdp(params["wk"], "fsdp", "kv_heads", "head_dim")
    wv = unshard_fsdp(params["wv"], "fsdp", "kv_heads", "head_dim")
    q = jnp.einsum("bsd,dhk->bshk", x, wq.astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, wk.astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, wv.astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.use_rope:
        from .layers import rope

        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale or (cfg.head_dim ** -0.5)
    q = q * scale
    # head-parallel attention: Q over 'model'; K/V shard kv_heads when
    # divisible, else replicate over 'model' (cheap — KV activations are
    # group_size-times smaller). The *decode cache* instead falls back to
    # head_dim sharding for memory (DESIGN.md §5.4).
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _group_q(q: jax.Array, num_kv: int) -> jax.Array:
    """[B,S,H,D] -> [B,S,Kv,G,D]"""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def _scores(q5, k):
    # q5: [B,Sq,Kv,G,D], k: [B,Sk,Kv,D] -> [B,Kv,G,Sq,Sk]  (f32)
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", q5, k, preferred_element_type=jnp.float32
    )


def _attend_dense(
    q, k, v, *, causal: bool, window: Optional[int],
    logit_cap: Optional[float], q_positions, k_positions,
) -> jax.Array:
    b, sq, h, d = q.shape
    kv = k.shape[2]
    q5 = _group_q(q, kv)
    s = _scores(q5, k)  # [B,Kv,G,Sq,Sk] f32
    s = softcap(s, logit_cap) if logit_cap else s
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= q_positions[:, None] >= k_positions[None, :]
    if window is not None:
        mask &= q_positions[:, None] - k_positions[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def _attend_blockwise(
    q, k, v, *, causal: bool, window: Optional[int],
    logit_cap: Optional[float], chunk_q: int,
) -> jax.Array:
    """Flash-style online-softmax over query chunks.

    Global-causal: each chunk attends over the full (masked) key range but
    only one [chunk, Sk] score block is live at a time.
    Sliding-window: each chunk attends a dynamic KV slice of static size
    window+chunk — true sub-quadratic compute.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    assert sq % chunk_q == 0, (sq, chunk_q)
    nchunk = sq // chunk_q
    qc = q.reshape(b, nchunk, chunk_q, h, d).transpose(1, 0, 2, 3, 4)

    local = window is not None and (window + chunk_q) < sk
    if local:
        span = window + chunk_q  # static slice width
        # pad keys on the left so every slice is in-bounds
        pad = span - chunk_q
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    def body(carry, ci):
        qi = qc[ci]  # [B,chunk,H,D] — gather of one chunk
        q_pos = ci * chunk_q + jnp.arange(chunk_q)
        q5 = _group_q(qi, kvh)
        if local:
            start = ci * chunk_q  # in padded coords == q_start - pad + pad
            ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            k_pos = start - pad + jnp.arange(span)
        else:
            ks, vs = k, v
            k_pos = jnp.arange(sk)
        s = _scores(q5, ks)
        s = softcap(s, logit_cap) if logit_cap else s
        mask = jnp.ones((chunk_q, ks.shape[1]), dtype=bool)
        mask &= k_pos[None, :] >= 0  # padded region
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vs.dtype), vs)
        return carry, o.reshape(b, chunk_q, h, d)

    _, chunks = jax.lax.scan(body, None, jnp.arange(nchunk))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    return out


def self_attention(
    params,
    x: jax.Array,
    cfg: AttnConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence self attention (train / prefill).

    Returns (output, (k, v)) so prefill can populate the cache.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, x, cfg, positions)
    if s <= cfg.dense_threshold or s % cfg.chunk_q != 0:
        out = _attend_dense(
            q, k, v, causal=causal, window=window, logit_cap=cfg.logit_cap,
            q_positions=positions, k_positions=positions,
        )
    else:
        out = _attend_blockwise(
            q, k, v, causal=causal, window=window, logit_cap=cfg.logit_cap,
            chunk_q=cfg.chunk_q,
        )
    wo = unshard_fsdp(params["wo"], "heads", "head_dim", "fsdp")
    proj = jnp.einsum("bshk,hkd->bsd", out, wo.astype(x.dtype))
    return proj, (k, v)


def cross_attention(
    params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
    cfg: AttnConfig,
) -> jax.Array:
    """Decoder->encoder attention; enc_kv precomputed (k, v)."""
    b, s, _ = x.shape
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
    q = q * (cfg.query_scale or cfg.head_dim ** -0.5)
    k, v = enc_kv
    sq, sk = s, k.shape[1]
    out = _attend_dense(
        q, k, v, causal=False, window=None, logit_cap=cfg.logit_cap,
        q_positions=jnp.arange(sq), k_positions=jnp.arange(sk),
    )
    wo = unshard_fsdp(params["wo"], "heads", "head_dim", "fsdp")
    return jnp.einsum("bshk,hkd->bsd", out, wo.astype(dtype))


def cross_kv(params, enc_out: jax.Array, cfg: AttnConfig):
    dtype = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    return k, v


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)
# ---------------------------------------------------------------------------

def decode_attention(
    params,
    x: jax.Array,  # [B, 1, d_model]
    cache_k: jax.Array,  # [B, Smax, Kv, D]
    cache_v: jax.Array,
    pos: jax.Array,  # scalar int32: index where the new token goes
    cfg: AttnConfig,
    *,
    window: Optional[int] = None,
    ring: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. Returns (out, new_cache_k, new_cache_v).

    ``ring=True`` (sliding-window layers): the cache capacity equals the
    window and writes wrap at ``pos % cap``. RoPE is applied before
    caching (absolute positions), softmax is order-invariant, and by
    construction every resident entry lies within the window, so no
    window mask is needed — only a fill mask while pos+1 < cap. This is
    the §Perf memory optimization for long-context local layers."""
    b, one, _ = x.shape
    dtype = x.dtype
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    smax = cache_k.shape[1]
    write_at = (pos % smax) if ring else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), write_at, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), write_at, axis=1
    )
    kvh = cache_k.shape[2]
    q5 = _group_q(q, kvh)  # [B,1,Kv,G,D]
    s = _scores(q5, cache_k.astype(dtype))  # [B,Kv,G,1,Smax]
    s = softcap(s, cfg.logit_cap) if cfg.logit_cap else s
    k_pos = jnp.arange(smax)
    if ring:
        mask = k_pos <= pos  # fill mask; window implicit in capacity
    else:
        mask = k_pos <= pos
        if window is not None:
            mask &= k_pos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(dtype),
                   cache_v.astype(dtype))
    o = o.reshape(b, 1, q.shape[2], q.shape[3])
    wo2 = unshard_fsdp(params["wo"], "heads", "head_dim", "fsdp")
    out = jnp.einsum("bshk,hkd->bsd", o, wo2.astype(dtype))
    return out, cache_k, cache_v
