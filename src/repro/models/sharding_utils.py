"""Ambient-mesh-aware activation sharding constraints.

GSPMD propagates parameter shardings through simple stacks, but
heterogeneous layers (SSD's multi-operand einsums, MoE scatter/gather)
can make the propagator choose replication for large intermediates —
observed as multi-GB all-gathers in the mamba2 dry-run baseline
(EXPERIMENTS.md §Dry-run notes). Pinning a handful of activations fixes
the search space. `constrain` resolves LOGICAL names against whatever
mesh is ambient (jax.set_mesh or the legacy `with mesh:` context) and
no-ops when there is none, so the same model code runs in smoke tests
(1 device), dry-runs (512 fake devices) and real launches.
"""

from __future__ import annotations

from typing import Optional, Set, Tuple

import jax
from jax.sharding import PartitionSpec as P

# logical activation axis -> preferred mesh axes (first that divides)
ACT_MAP = {
    "batch": ("pod", "data"),
    "seq_model": ("model",),  # sequence parallelism (residual stream)
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "vocab": ("model",),
    "embed": (),
    "fsdp": (),  # at USE time fsdp dims are gathered (see unshard_fsdp)
    None: (),
}


_ACT_OVERRIDES: dict = {}


class use_act_map:
    """Temporarily override ACT_MAP entries (parallelism policies):
    e.g. pure-FSDP lowers with heads/mlp unmapped and batch spanning
    every mesh axis. Used by launch/dryrun for per-arch policies."""

    def __init__(self, overrides: dict):
        self.overrides = overrides
        self.saved: dict = {}

    def __enter__(self):
        global _ACT_OVERRIDES
        self.saved = dict(_ACT_OVERRIDES)
        _ACT_OVERRIDES.update(self.overrides)
        return self

    def __exit__(self, *exc):
        global _ACT_OVERRIDES
        _ACT_OVERRIDES.clear()
        _ACT_OVERRIDES.update(self.saved)
        return False


def _act_axes(name):
    if name in _ACT_OVERRIDES:
        return _ACT_OVERRIDES[name]
    return ACT_MAP.get(name, ())


def ambient_axis_sizes() -> dict:
    # jax.sharding.get_abstract_mesh only exists in newer JAX releases
    # (>= 0.5); on 0.4.x fall through to the legacy mesh context.
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        am = get_am()
        if am is not None and not am.empty:
            return dict(zip(am.axis_names, am.axis_sizes))
    try:  # legacy `with mesh:` context
        from jax._src import mesh as mesh_lib

        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return dict(zip(pm.axis_names, pm.devices.shape))
    except Exception:  # pragma: no cover - defensive
        pass
    return {}


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op w/o mesh.

    Divisibility is checked per dim; mesh axes are never reused across
    dims of one constraint (mirrors params.resolve_pspec).
    """
    sizes = ambient_axis_sizes()
    if not sizes:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    used: Set[str] = set()
    entries = []
    for dim, name in zip(x.shape, logical):
        acc: Tuple[str, ...] = ()
        prod = 1
        for a in _act_axes(name):
            if a in sizes and a not in used \
                    and dim % (prod * sizes[a]) == 0:
                acc = acc + (a,)
                prod *= sizes[a]
        used.update(acc)
        if len(acc) == 0:
            entries.append(None)
        elif len(acc) == 1:
            entries.append(acc[0])
        else:
            entries.append(acc)
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except (RuntimeError, ValueError):  # no usable mesh
        return x


def unshard_fsdp(w: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Weight-gather FSDP: at rest, parameters are additionally sharded
    over the data axes on their 'fsdp' dim (ZeRO-3); at USE they must be
    gathered, otherwise GSPMD contracts the sharded dim and all-reduces
    ACTIVATION-sized partials over the data axis (observed: 550 GB/dev
    wire on minitron-8b train — EXPERIMENTS.md §Perf baseline notes).
    Constraining the use-site to fsdp→replicated makes XLA insert the
    standard per-block bf16 weight all-gather instead, which is smaller
    by activations/params orders of magnitude. Tensor-parallel ('model')
    dims are preserved."""
    return constrain(w, *logical)
