"""Mixture-of-Experts layer: top-k routing, capacity dispatch, shared experts.

TPU-native dispatch design (documented in DESIGN.md §5.4): the classical
GShard/Switch dispatch einsum materializes a [T, E, C] one-hot tensor whose
size is quadratic in tokens (C ∝ T·k/E). Hydra-JAX instead computes each
token copy's *position within its expert* with an exclusive cumsum over the
token axis ([T, E] int32, linear memory), then scatters token rows into a
[E·C, D] buffer and gathers them back — overflow beyond capacity C is
dropped exactly like capacity-factor routing in GShard/Switch/MaxText.
Expert weights are stacked [E, ...] and sharded over the 'model' axis; the
scatter/gather across the expert axis is GSPMD's all-to-all equivalent.

Routing semantics follow DBRX/DeepSeek-MoE: softmax router, top-k with
renormalized weights, optional shared experts applied densely, Switch-style
load-balance auxiliary loss and router z-loss.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import act_fn, mlp_apply, mlp_specs
from .params import ParamSpec
from .sharding_utils import constrain, unshard_fsdp


class MoEConfig(NamedTuple):
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0  # shared experts (deepseek), each of d_ff_expert
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2


def moe_specs(d_model: int, cfg: MoEConfig, dtype) -> Dict[str, Any]:
    e, f = cfg.num_experts, cfg.d_ff_expert
    specs: Dict[str, Any] = {
        "router": ParamSpec((d_model, e), ("embed", None), dtype=jnp.float32,
                            init="scaled", fan_in_axes=(0,)),
        "wi_gate": ParamSpec((e, d_model, f), ("experts", "fsdp", "mlp"),
                             dtype=dtype, init="scaled", fan_in_axes=(1,)),
        "wi_up": ParamSpec((e, d_model, f), ("experts", "fsdp", "mlp"),
                           dtype=dtype, init="scaled", fan_in_axes=(1,)),
        "wo": ParamSpec((e, f, d_model), ("experts", "mlp", "fsdp"),
                        dtype=dtype, init="scaled", fan_in_axes=(1,)),
    }
    if cfg.num_shared > 0:
        specs["shared"] = mlp_specs(d_model, cfg.num_shared * f, dtype)
    return specs


def _route(
    logits: jax.Array, cfg: MoEConfig
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Top-k routing. logits [T, E] -> (weights [T,K], idx [T,K], aux)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9
    )
    # Switch aux loss: E * sum_e (fraction dispatched_e * mean prob_e)
    t = logits.shape[0]
    one_hot_topk = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32)
    frac = one_hot_topk.sum(axis=(0, 1)) / (t * cfg.top_k)
    mean_prob = probs.mean(axis=0)
    aux_loss = cfg.num_experts * jnp.sum(frac * mean_prob)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    z_loss = jnp.mean(jnp.square(lse))
    aux = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_expert_frac_max": frac.max(),
    }
    return weights, idx, aux


def moe_apply(
    params: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: MoEConfig,
    *,
    act: str = "silu",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Grouped capacity routing (GShard-style groups = sequences).

    Position-in-expert is computed with a cumsum over the SEQUENCE axis
    of each batch row, so routing math never crosses the batch shards —
    the baseline global-token cumsum serialized over the (pod, data)
    axes and dominated the MoE dry-run collectives (EXPERIMENTS.md
    §Perf, dbrx cells). Capacity is per (group, expert); only the
    expert-buffer scatter/gather crosses shards (the all-to-all).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.num_experts, cfg.top_k
    dtype = x.dtype

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), params["router"]
    )
    weights, idx, aux = _route(logits, cfg)

    # capacity per expert (static)
    capacity = int(max(k, round(t * k * cfg.capacity_factor / e)))
    capacity = max(8, capacity)

    # position of each (token, k) copy within its expert, token-major
    # order. NOTE (EXPERIMENTS.md §Perf A): this global-token cumsum is
    # the measured-best of three dispatch plans — per-sequence grouped
    # routing regressed 8.4x (A1: GSPMD replicates+reduces scatter
    # buffers across the model axis) and staged grouped routing 1.2x
    # (A2); a shard_map ragged all-to-all is the logged next iteration.
    running = jnp.zeros((e,), jnp.int32)
    pos_list = []
    for kk in range(k):
        mask_k = jax.nn.one_hot(idx[:, kk], e, dtype=jnp.int32)  # [T,E]
        within = jnp.cumsum(mask_k, axis=0) - mask_k  # exclusive cumsum
        pos_k = jnp.take_along_axis(
            within + running[None, :], idx[:, kk:kk + 1], axis=1
        )[:, 0]
        running = running + mask_k.sum(axis=0)
        pos_list.append(pos_k)
    pos = jnp.stack(pos_list, axis=1)  # [T, K]

    keep = pos < capacity
    dest = jnp.where(keep, idx * capacity + pos, e * capacity)  # OOB drop

    # scatter token rows into expert buffers [E*C, D]
    dest_flat = dest.reshape(t * k)
    x_rep = jnp.repeat(xt, k, axis=0)  # token-major [T*K, D]
    buf = jnp.zeros((e * capacity, d), dtype)
    buf = buf.at[dest_flat].set(x_rep, mode="drop")
    buf = buf.reshape(e, capacity, d)
    # expert-parallel: buffers live where the expert weights live
    buf = constrain(buf, "experts", None, None)

    wg = unshard_fsdp(params["wi_gate"], "experts", "fsdp", "mlp")
    wu = unshard_fsdp(params["wi_up"], "experts", "fsdp", "mlp")
    wo = unshard_fsdp(params["wo"], "experts", "mlp", "fsdp")
    gate = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype))
    h = act_fn(act)(gate) * up
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(dtype))
    out_buf = constrain(out_buf, "experts", None, None)
    out_buf = out_buf.reshape(e * capacity, d)

    # gather back, weight, sum over k copies
    gathered = jnp.take(out_buf, jnp.minimum(dest_flat, e * capacity - 1),
                        axis=0)
    gathered = jnp.where(keep.reshape(t * k, 1), gathered, 0.0)
    wflat = weights.reshape(t * k, 1).astype(dtype)
    out = (gathered * wflat).reshape(t, k, d).sum(axis=1)

    if cfg.num_shared > 0:
        out = out + mlp_apply(params["shared"], xt, act=act)

    aux["moe_dropped_frac"] = 1.0 - keep.mean()
    return out.reshape(b, s, d), aux


def moe_loss(aux: Dict[str, jax.Array], cfg: MoEConfig) -> jax.Array:
    return (cfg.aux_loss_weight * aux["moe_aux_loss"]
            + cfg.router_z_loss * aux["moe_z_loss"])
