"""Shared neural building blocks: norms, MLPs, embeddings, RoPE, softcap.

All modules follow the same convention: ``<name>_specs(cfg...)`` returns a
ParamSpec pytree, ``<name>_apply(params, x, ...)`` is a pure function.
Compute runs in ``cfg.compute_dtype`` (bf16 by default) with f32 reductions
where it matters (norm statistics, softmax, loss).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .params import ParamSpec


# ---------------------------------------------------------------------------
# activations / misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 style logit soft capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_specs(dim: int, dtype=jnp.float32) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((dim,), ("embed",), dtype=dtype, init="zeros")}


def rmsnorm_apply(
    params: Dict[str, jax.Array],
    x: jax.Array,
    eps: float = 1e-6,
    *,
    plus_one: bool = True,
) -> jax.Array:
    """RMSNorm with (1 + scale) parameterization (llama/gemma convention).

    Statistics in f32 regardless of the compute dtype.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    w = (1.0 + scale) if plus_one else scale
    return (xn * w).astype(dtype)


# ---------------------------------------------------------------------------
# Dense / gated MLP
# ---------------------------------------------------------------------------

def dense_specs(
    d_in: int,
    d_out: Tuple[int, ...],
    logical_in: str,
    logical_out: Tuple[str, ...],
    dtype,
    *,
    bias: bool = False,
) -> Dict[str, ParamSpec]:
    shape = (d_in,) + d_out
    logical = (logical_in,) + logical_out
    specs = {
        "w": ParamSpec(shape, logical, dtype=dtype, init="scaled",
                       fan_in_axes=(0,))
    }
    if bias:
        specs["b"] = ParamSpec(d_out, logical_out, dtype=dtype, init="zeros")
    return specs


def mlp_specs(d_model: int, d_ff: int, dtype) -> Dict[str, Any]:
    """SwiGLU MLP (gate, up, down)."""
    return {
        "wi_gate": ParamSpec((d_model, d_ff), ("fsdp", "mlp"), dtype=dtype,
                             init="scaled", fan_in_axes=(0,)),
        "wi_up": ParamSpec((d_model, d_ff), ("fsdp", "mlp"), dtype=dtype,
                           init="scaled", fan_in_axes=(0,)),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "fsdp"), dtype=dtype,
                        init="scaled", fan_in_axes=(0,)),
    }


def mlp_apply(params, x: jax.Array, act: str = "silu") -> jax.Array:
    from .sharding_utils import unshard_fsdp

    dtype = x.dtype
    wg = unshard_fsdp(params["wi_gate"], "fsdp", "mlp").astype(dtype)
    wu = unshard_fsdp(params["wi_up"], "fsdp", "mlp").astype(dtype)
    wo = unshard_fsdp(params["wo"], "mlp", "fsdp").astype(dtype)
    gate = jnp.einsum("...d,df->...f", x, wg)
    up = jnp.einsum("...d,df->...f", x, wu)
    h = act_fn(act)(gate) * up
    return jnp.einsum("...f,fd->...d", h, wo)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_specs(vocab: int, d_model: int, dtype) -> Dict[str, ParamSpec]:
    return {
        "embedding": ParamSpec(
            (vocab, d_model), ("vocab", "embed"), dtype=dtype,
            init="embed", scale=1.0,
        )
    }


def embed_apply(params, tokens: jax.Array, compute_dtype) -> jax.Array:
    emb = params["embedding"].astype(compute_dtype)
    return jnp.take(emb, tokens, axis=0)


def logits_apply(
    params, x: jax.Array, *, tied: bool, head_params=None,
    final_softcap: Optional[float] = None,
) -> jax.Array:
    from .sharding_utils import unshard_fsdp

    if tied:
        w = params["embedding"].astype(x.dtype)
        logits = jnp.einsum("...d,vd->...v", x, w)
    else:
        w = unshard_fsdp(head_params["w"], "fsdp", "vocab").astype(
            x.dtype)
        logits = jnp.einsum("...d,dv->...v", x, w)
    return softcap(logits, final_softcap)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Apply rotary embeddings.

    x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq].
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = jnp.arange(half, dtype=jnp.float32)
    inv_freq = 1.0 / (theta ** (freq / half))
    # angles: [..., seq, half]
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    sin = jnp.sin(angles)[..., None, :]  # broadcast over heads
    cos = jnp.cos(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None,
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-mean cross entropy in f32 with optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {
        "loss": loss,
        "ntokens": mask.sum(),
        "ppl_proxy": loss,
    }
    return loss, metrics
