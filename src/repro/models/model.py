"""Model facade: one entry point for all 10 architectures.

* ``model_specs(cfg)``   — full parameter ParamSpec pytree
* ``loss_fn``            — train forward + CE loss (+ MoE aux)
* ``prefill``            — full-sequence forward emitting a decode cache
* ``decode_step``        — one-token step against the cache
* ``input_specs``        — ParamSpec pytree for each assigned shape
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

from . import encdec as encdec_mod
from . import transformer as tfm
from .layers import (cross_entropy, embed_apply, embed_specs, logits_apply,
                     rmsnorm_apply, rmsnorm_specs)
from .params import ParamSpec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": embed_specs(cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "final_norm": rmsnorm_specs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["head"] = {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size),
                           ("fsdp", "vocab"), dtype=cfg.param_dtype,
                           init="scaled", fan_in_axes=(0,))
        }
    if cfg.is_encdec:
        specs["encdec"] = encdec_mod.encdec_specs(cfg)
        return specs
    if cfg.dense_first_layer:
        from repro.configs.base import LayerDesc

        specs["first_layer"] = tfm.sublayer_specs(
            cfg, LayerDesc(kind="attn", ff="dense"),
            d_ff_override=cfg.dense_first_d_ff or cfg.d_ff,
        )
    specs["blocks"] = tfm.stack_specs(tfm.block_specs(cfg), cfg.num_blocks)
    return specs


def _embed(params, tokens, cfg: ModelConfig):
    x = embed_apply(params["embed"], tokens, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return x


def _logits(params, x, cfg: ModelConfig):
    return logits_apply(
        params["embed"], x, tied=cfg.tie_embeddings,
        head_params=params.get("head"),
        final_softcap=cfg.final_logit_softcap,
    )


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------

def _backbone(params, tokens, cfg: ModelConfig, collect_cache=False):
    positions = jnp.arange(tokens.shape[1])
    x = _embed(params, tokens, cfg)
    first_cache = None
    moe0 = jnp.zeros((), jnp.float32)
    if cfg.dense_first_layer:
        from repro.configs.base import LayerDesc

        x, moe0, first_cache = tfm._apply_sublayer(
            params["first_layer"], x, LayerDesc(kind="attn", ff="dense"),
            cfg, positions, collect_cache,
        )
    x, moe_loss, caches = tfm.run_blocks(
        params["blocks"], x, cfg, positions, collect_cache
    )
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, moe0 + moe_loss, (first_cache, caches)


def loss_fn(
    params, batch: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens/labels [B,S] (+frames for enc-dec)."""
    if cfg.is_encdec:
        enc_out = encdec_mod.encode(params["encdec"], batch["frames"], cfg)
        x = _embed(params, batch["tokens"], cfg)
        x = encdec_mod.decode_train(params["encdec"], enc_out, x, cfg)
        moe_loss = jnp.zeros((), jnp.float32)
    else:
        x, moe_loss, _ = _backbone(params, batch["tokens"], cfg)
    logits = _logits(params, x, cfg)
    loss, metrics = cross_entropy(
        logits, batch["labels"], batch.get("mask")
    )
    total = loss + moe_loss
    metrics["moe_loss"] = moe_loss
    metrics["total_loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Returns (last-position logits, cache)."""
    if cfg.is_encdec:
        enc_out = encdec_mod.encode(params["encdec"], batch["frames"], cfg)
        x = _embed(params, batch["tokens"], cfg)
        x, cache = encdec_mod.decode_train(
            params["encdec"], enc_out, x, cfg, collect_cache=True
        )
        logits = _logits(params, x[:, -1:, :], cfg)
        return logits, cache
    x, _, (first_cache, caches) = _backbone(
        params, batch["tokens"], cfg, collect_cache=True
    )
    logits = _logits(params, x[:, -1:, :], cfg)
    cache = {"blocks": caches}
    if first_cache is not None:
        cache["first_layer"] = first_cache
    return logits, cache


def decode_step(params, tokens, cache, pos, cfg: ModelConfig):
    """tokens [B,1] int32; pos scalar int32. Returns (logits, new cache)."""
    x = _embed(params, tokens, cfg)
    if cfg.is_encdec:
        x, new_cache = encdec_mod.decode_step(
            params["encdec"], x, cache, pos, cfg
        )
        return _logits(params, x, cfg), new_cache
    new_cache = {}
    if cfg.dense_first_layer:
        from repro.configs.base import LayerDesc

        x, ne = tfm._sublayer_decode(
            params["first_layer"], x, LayerDesc(kind="attn", ff="dense"),
            cfg, cache["first_layer"], pos,
        )
        new_cache["first_layer"] = ne
    x, nb = tfm.decode_blocks(params["blocks"], x, cfg, cache["blocks"], pos)
    new_cache["blocks"] = nb
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# Input / cache specs per assigned shape
# ---------------------------------------------------------------------------

def decode_cache_specs(cfg: ModelConfig, batch: int, seq: int):
    if cfg.is_encdec:
        return encdec_mod.encdec_cache_specs(cfg, batch, seq)
    cache = {"blocks": tfm.cache_specs(cfg, batch, seq)}
    if cfg.dense_first_layer:
        from repro.configs.base import LayerDesc

        cache["first_layer"] = tfm.sublayer_cache_spec(
            cfg, LayerDesc(kind="attn", ff="dense"), batch, seq
        )
    return cache


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ParamSpec pytree of every model input for (cfg, shape).

    Converted to ShapeDtypeStructs (dry-run) or materialized (smoke tests)
    via params.abstract / params.initialize.
    """
    b, s = shape.batch, shape.seq
    tok = lambda shp: ParamSpec(shp, ("batch", "seq"), dtype=jnp.int32,
                                init="zeros")
    if shape.kind == "train":
        specs = {"tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.is_encdec:
            specs["frames"] = ParamSpec(
                (b, cfg.encoder_frames, cfg.d_model),
                ("batch", "seq", "embed"), dtype=cfg.compute_dtype,
                init="normal", scale=1.0,
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok((b, s))}
        if cfg.is_encdec:
            specs["frames"] = ParamSpec(
                (b, cfg.encoder_frames, cfg.d_model),
                ("batch", "seq", "embed"), dtype=cfg.compute_dtype,
                init="normal", scale=1.0,
            )
        return specs
    if shape.kind == "decode":
        return {
            "tokens": tok((b, 1)),
            "cache": decode_cache_specs(cfg, b, s),
            "pos": ParamSpec((), (), dtype=jnp.int32, init="zeros"),
        }
    raise ValueError(shape.kind)
