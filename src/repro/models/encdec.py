"""Encoder-decoder backbone (seamless-m4t-medium stub-frontend variant).

Per the assignment, the modality frontend is a STUB: ``input_specs()``
supplies precomputed audio *frame embeddings* [B, frames, d_model]. The
encoder is a bidirectional transformer over frames; the decoder is a causal
transformer with cross-attention. Decoder KV (self) and encoder KV (cross)
are cached for decoding.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn_mod
from .layers import mlp_apply, mlp_specs, rmsnorm_apply, rmsnorm_specs
from .params import ParamSpec
from .transformer import _remat, attn_config, stack_specs


def enc_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = cfg.param_dtype
    return {
        "ln1": rmsnorm_specs(cfg.d_model),
        "attn": attn_mod.attn_specs(attn_config(cfg), dt),
        "ln2": rmsnorm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, dt),
    }


def dec_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = cfg.param_dtype
    return {
        "ln1": rmsnorm_specs(cfg.d_model),
        "self_attn": attn_mod.attn_specs(attn_config(cfg), dt),
        "ln_x": rmsnorm_specs(cfg.d_model),
        "cross_attn": attn_mod.attn_specs(attn_config(cfg), dt),
        "ln2": rmsnorm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, dt),
    }


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "frontend_proj": ParamSpec(
            (cfg.d_model, cfg.d_model), ("fsdp", "embed"),
            dtype=cfg.param_dtype, init="scaled", fan_in_axes=(0,)),
        "encoder": stack_specs(enc_layer_specs(cfg), cfg.encoder_layers),
        "enc_norm": rmsnorm_specs(cfg.d_model),
        "decoder": stack_specs(dec_layer_specs(cfg), cfg.num_layers),
        "dec_norm": rmsnorm_specs(cfg.d_model),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: [B, F, d_model] stub embeddings -> encoder output."""
    acfg = attn_config(cfg)
    x = jnp.einsum("bfd,de->bfe", frames.astype(cfg.compute_dtype),
                   params["frontend_proj"].astype(cfg.compute_dtype))
    positions = jnp.arange(frames.shape[1])

    def body(h, lp):
        a = rmsnorm_apply(lp["ln1"], h, cfg.norm_eps)
        a, _ = attn_mod.self_attention(lp["attn"], a, acfg, causal=False,
                                       positions=positions)
        h = h + a
        m = rmsnorm_apply(lp["ln2"], h, cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], m, act=cfg.act)
        return h, None

    body = _remat(body, cfg.remat_policy)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


def decode_train(
    params, enc_out: jax.Array, x: jax.Array, cfg: ModelConfig,
    collect_cache: bool = False,
):
    """Teacher-forced decoder pass over embedded targets x [B,S,d]."""
    acfg = attn_config(cfg)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        a = rmsnorm_apply(lp["ln1"], h, cfg.norm_eps)
        a, (k, v) = attn_mod.self_attention(lp["self_attn"], a, acfg,
                                            causal=True,
                                            positions=positions)
        h = h + a
        c = rmsnorm_apply(lp["ln_x"], h, cfg.norm_eps)
        ck, cv = attn_mod.cross_kv(lp["cross_attn"], enc_out, acfg)
        c = attn_mod.cross_attention(lp["cross_attn"], c, (ck, cv), acfg)
        h = h + c
        m = rmsnorm_apply(lp["ln2"], h, cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], m, act=cfg.act)
        cache = ({"k": k, "v": v, "ck": ck, "cv": cv}
                 if collect_cache else None)
        return h, cache

    body = _remat(body, cfg.remat_policy)
    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = rmsnorm_apply(params["dec_norm"], x, cfg.norm_eps)
    return (x, caches) if collect_cache else x


def decode_step(params, x: jax.Array, cache, pos, cfg: ModelConfig):
    """One decoder token. x [B,1,d]; cache has self k/v + cross ck/cv."""
    acfg = attn_config(cfg)

    def body(h, scanned):
        lp, lc = scanned
        a = rmsnorm_apply(lp["ln1"], h, cfg.norm_eps)
        a, nk, nv = attn_mod.decode_attention(
            lp["self_attn"], a, lc["k"], lc["v"], pos, acfg
        )
        h = h + a
        c = rmsnorm_apply(lp["ln_x"], h, cfg.norm_eps)
        c = attn_mod.cross_attention(
            lp["cross_attn"], c, (lc["ck"], lc["cv"]), acfg
        )
        h = h + c
        m = rmsnorm_apply(lp["ln2"], h, cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], m, act=cfg.act)
        return h, {"k": nk, "v": nv, "ck": lc["ck"], "cv": lc["cv"]}

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    x = rmsnorm_apply(params["dec_norm"], x, cfg.norm_eps)
    return x, new_cache


def encdec_cache_specs(cfg: ModelConfig, batch: int, seq: int):
    dt = cfg.compute_dtype
    kvshape = (batch, seq, cfg.num_kv_heads, cfg.head_dim)
    xshape = (batch, cfg.encoder_frames, cfg.num_kv_heads, cfg.head_dim)
    lay = ("batch", "seq", "kv_heads", "head_dim")
    layer = {
        "k": ParamSpec(kvshape, lay, dtype=dt, init="zeros"),
        "v": ParamSpec(kvshape, lay, dtype=dt, init="zeros"),
        "ck": ParamSpec(xshape, lay, dtype=dt, init="zeros"),
        "cv": ParamSpec(xshape, lay, dtype=dt, init="zeros"),
    }
    return stack_specs(layer, cfg.num_layers)
