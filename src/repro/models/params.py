"""Parameter specification / initialization / sharding substrate.

Hydra-JAX has no flax dependency: every model declares its parameters as a
pytree of :class:`ParamSpec` (shape + logical axis names + init rule).  From
that single declaration we derive

* ``abstract(tree)``        -> ShapeDtypeStruct pytree (dry-run, no alloc)
* ``initialize(tree, key)`` -> materialized arrays (tests / real training)
* ``partition(tree, rules, mesh)`` -> PartitionSpec pytree (pjit shardings)

Logical axis names ('embed', 'heads', 'mlp', 'vocab', 'experts', ...) are
resolved to physical mesh axes through prioritized *rules*, MaxText-style.
A rule maps a logical axis to one mesh axis, a tuple of mesh axes (the dim
is sharded over their product) or None.  Resolution is conservative: a
mapping is dropped when the dimension is not divisible by the mesh axes'
product or when a mesh axis was already consumed by an earlier dim, so a
single rule set serves every architecture (e.g. GQA kv_heads=8 simply does
not bind a 16-way 'model' axis and the 'head_dim' rule picks it up instead).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axis = Optional[str]
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""

    shape: Tuple[int, ...]
    logical: Tuple[Axis, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | scaled | constant
    scale: Optional[float] = None  # stddev (normal/scaled) or constant value
    fan_in_axes: Tuple[int, ...] = ()  # dims treated as fan-in for 'scaled'

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"shape {self.shape} and logical {self.logical} rank mismatch"
            )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract(tree):
    """ShapeDtypeStruct pytree — zero allocation, for .lower() dry-runs."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree
    )


def param_count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(l.size for l in leaves if is_spec(l))


def param_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(
        l.size * jnp.dtype(l.dtype).itemsize for l in leaves if is_spec(l)
    )


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.scale or 0.0, spec.dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 1.0
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    if spec.init in ("normal", "scaled"):
        if spec.scale is not None and spec.init == "normal":
            std = spec.scale
        else:
            fan_axes = spec.fan_in_axes or (0,)
            fan_in = max(1, int(np.prod([spec.shape[a] for a in fan_axes])))
            std = (spec.scale or 1.0) / math.sqrt(fan_in)
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def initialize(tree, key: jax.Array):
    """Materialize a ParamSpec pytree into real arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = [
        _init_one(l, k) if is_spec(l) else l for l, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Logical -> physical sharding resolution
# ---------------------------------------------------------------------------

def _as_tuple(mx: MeshAxes) -> Tuple[str, ...]:
    if mx is None:
        return ()
    if isinstance(mx, str):
        return (mx,)
    return tuple(mx)


def resolve_pspec(
    logical: Sequence[Axis],
    shape: Sequence[int],
    rules: Dict[str, MeshAxes],
    mesh_shape: Dict[str, int],
) -> P:
    """Resolve logical axes to a PartitionSpec under divisibility constraints.

    Later dims never reuse a mesh axis consumed by an earlier dim; a rule
    that does not divide the dimension evenly is skipped (partial prefixes
    of a multi-axis rule are allowed, e.g. ('data','model') degrades to
    ('data',) when only the data factor divides).
    """
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        entry: Tuple[str, ...] = ()
        if name is not None and name in rules:
            cand = [a for a in _as_tuple(rules[name]) if a not in used]
            # greedy prefix that divides the dim
            acc: list = []
            prod = 1
            for a in cand:
                if dim % (prod * mesh_shape.get(a, 1)) == 0:
                    acc.append(a)
                    prod *= mesh_shape.get(a, 1)
            entry = tuple(acc)
        used.update(entry)
        if len(entry) == 0:
            out.append(None)
        elif len(entry) == 1:
            out.append(entry[0])
        else:
            out.append(entry)
    # strip trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def partition_specs(tree, rules: Dict[str, MeshAxes], mesh: Mesh):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_specs(
        lambda s: resolve_pspec(s.logical, s.shape, rules, mesh_shape), tree
    )


def shardings(tree, rules: Dict[str, MeshAxes], mesh: Mesh):
    specs = partition_specs(tree, rules, mesh)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# Default rule set shared by all architectures. 'fsdp' behaviour comes from
# mapping the embed/mlp fan dims onto the data axis *after* model axes; the
# resolver guarantees no axis is double-booked within a tensor.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    # params — tensor parallel first, then fsdp over data
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": "model",
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "fsdp": ("pod", "data"),  # fan-in dim of big matrices
    "layers": None,  # scan axis, never sharded
    "conv": None,
}


def logical_sds(
    shape: Sequence[int],
    logical: Sequence[Axis],
    dtype,
    rules: Dict[str, MeshAxes],
    mesh: Mesh,
) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct carrying a NamedSharding (for dry-run inputs)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = resolve_pspec(logical, shape, rules, mesh_shape)
    return jax.ShapeDtypeStruct(
        tuple(shape), dtype, sharding=NamedSharding(mesh, spec)
    )
