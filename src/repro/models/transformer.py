"""Decoder-only transformer assembly: scan-over-blocks, prefill, decode.

Depth is organized as ``num_blocks`` repetitions of the config's layer
*pattern* (period P). Parameters for one block are stacked along a leading
'layers' axis and the forward pass is a single ``lax.scan`` over blocks —
HLO size is O(P), not O(depth), which keeps 126-layer dry-run compiles
tractable and matches production practice (MaxText-style). Each block is
wrapped in ``jax.checkpoint`` with a configurable policy.

Heterogeneous sub-layers (attn global/local, mamba, dense/moe FF) are
dispatched statically from the pattern — inside the scan every block is
structurally identical, so stacking is well-formed for every architecture
(jamba's 8-layer block carries 7 mamba caches + 1 KV cache per block).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerDesc, ModelConfig

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import mlp_apply, mlp_specs, rmsnorm_apply, rmsnorm_specs
from .params import ParamSpec, tree_map_specs
from .sharding_utils import constrain


def attn_config(cfg: ModelConfig) -> attn_mod.AttnConfig:
    return attn_mod.AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        logit_cap=cfg.attn_logit_softcap,
        query_scale=cfg.query_scale,
        rope_theta=cfg.rope_theta,
        chunk_q=cfg.attn_chunk_q,
        dense_threshold=cfg.attn_dense_threshold,
    )


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def sublayer_specs(cfg: ModelConfig, desc: LayerDesc,
                   d_ff_override: int = 0) -> Dict[str, Any]:
    dt = cfg.param_dtype
    specs: Dict[str, Any] = {"ln1": rmsnorm_specs(cfg.d_model)}
    if desc.kind == "attn":
        specs["attn"] = attn_mod.attn_specs(attn_config(cfg), dt)
    else:
        specs["mamba"] = ssm_mod.ssm_specs(cfg.ssm, dt)
    if cfg.post_norm:
        specs["post_ln1"] = rmsnorm_specs(cfg.d_model)
    if desc.ff == "dense":
        specs["ln2"] = rmsnorm_specs(cfg.d_model)
        specs["mlp"] = mlp_specs(cfg.d_model, d_ff_override or cfg.d_ff, dt)
        if cfg.post_norm:
            specs["post_ln2"] = rmsnorm_specs(cfg.d_model)
    elif desc.ff == "moe":
        specs["ln2"] = rmsnorm_specs(cfg.d_model)
        specs["moe"] = moe_mod.moe_specs(cfg.d_model, cfg.moe, dt)
        if cfg.post_norm:
            specs["post_ln2"] = rmsnorm_specs(cfg.d_model)
    return specs


def block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        f"sub{i}": sublayer_specs(cfg, d)
        for i, d in enumerate(cfg.pattern)
    }


def stack_specs(tree, g: int):
    """Prepend a 'layers' axis of size g to every ParamSpec."""
    return tree_map_specs(
        lambda s: ParamSpec((g,) + s.shape, ("layers",) + s.logical,
                            dtype=s.dtype, init=s.init, scale=s.scale,
                            fan_in_axes=tuple(a + 1 for a in
                                              (s.fan_in_axes or (0,)))),
        tree,
    )


# ---------------------------------------------------------------------------
# Forward (train / prefill share one path; prefill also emits KV)
# ---------------------------------------------------------------------------

def _sp(cfg: ModelConfig):
    """Residual-stream seq axis under sequence parallelism."""
    return "seq_model" if cfg.sequence_parallel else None


def _apply_sublayer(
    p: Dict[str, Any],
    x: jax.Array,
    desc: LayerDesc,
    cfg: ModelConfig,
    positions: jax.Array,
    collect_cache: bool,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (x, moe_loss, cache_entry_or_None).

    Sequence parallelism (cfg.sequence_parallel): the residual stream x
    stays sharded (batch, seq->model); the pre-norm runs local, the
    normed input is gathered (all-gather over 'model') right before
    each mixer, and the mixer output is constrained back to
    seq-sharded — GSPMD then emits reduce-scatter instead of all-reduce
    for the TP output projections (Korthikanti et al.), halving wire
    bytes and running norms/residual adds 1/TP as much."""
    acfg = attn_config(cfg)
    cache_entry = None
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if cfg.sequence_parallel:
        h = constrain(h, "batch", None, None)  # gather seq for mixer
    if desc.kind == "attn":
        window = cfg.local_window if desc.attn_type == "local" else None
        out, (k, v) = attn_mod.self_attention(
            p["attn"], h, acfg, causal=True, window=window,
            positions=positions,
        )
        if collect_cache:
            cache_entry = {"k": k, "v": v}
    else:
        if collect_cache:
            out, cache_entry = ssm_mod.ssm_apply(
                p["mamba"], h, cfg.ssm, return_cache=True
            )
        else:
            out = ssm_mod.ssm_apply(p["mamba"], h, cfg.ssm)
    if cfg.sequence_parallel:
        out = constrain(out, "batch", _sp(cfg), None)  # reduce-scatter
    if cfg.post_norm:
        out = rmsnorm_apply(p["post_ln1"], out, cfg.norm_eps)
    x = x + out
    moe_loss = jnp.zeros((), jnp.float32)
    if desc.ff != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if cfg.sequence_parallel and desc.ff == "dense":
            h = constrain(h, "batch", None, None)
        if desc.ff == "dense":
            out = mlp_apply(p["mlp"], h, act=cfg.act)
        else:
            out, aux = moe_mod.moe_apply(p["moe"], h, cfg.moe, act=cfg.act)
            moe_loss = moe_mod.moe_loss(aux, cfg.moe)
        if cfg.sequence_parallel:
            out = constrain(out, "batch", _sp(cfg), None)
        if cfg.post_norm:
            out = rmsnorm_apply(p["post_ln2"], out, cfg.norm_eps)
        x = x + out
    return x, moe_loss, cache_entry


def _block_fwd(params, x, cfg: ModelConfig, positions, collect_cache: bool):
    moe_total = jnp.zeros((), jnp.float32)
    cache = {}
    x = constrain(x, "batch", _sp(cfg), None)
    for i, desc in enumerate(cfg.pattern):
        x, ml, ce = _apply_sublayer(
            params[f"sub{i}"], x, desc, cfg, positions, collect_cache
        )
        moe_total = moe_total + ml
        if ce is not None:
            cache[f"sub{i}"] = ce
    return x, moe_total, cache


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = {
        "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_saveable,
        "dots_no_batch": (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable),
    }[policy]
    return jax.checkpoint(fn, policy=pol)


def run_blocks(
    stacked_params, x: jax.Array, cfg: ModelConfig,
    positions: jax.Array, collect_cache: bool = False,
):
    """Scan the block stack. Returns (x, moe_loss, stacked_cache|None)."""

    def body(carry, bp):
        h, mt = carry
        h, ml, cache = _block_fwd(bp, h, cfg, positions, collect_cache)
        return (h, mt + ml), (cache if collect_cache else None)

    body = _remat(body, cfg.remat_policy)
    if cfg.scan_layers:
        (x, moe_total), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), stacked_params
        )
    else:
        moe_total = jnp.zeros((), jnp.float32)
        caches_list = []
        g = cfg.num_blocks
        for i in range(g):
            bp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        stacked_params)
            (x, moe_total), c = body((x, moe_total), bp)
            caches_list.append(c)
        caches = (jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *caches_list)
            if collect_cache else None)
    return x, moe_total, caches


# ---------------------------------------------------------------------------
# Decode (single token through all blocks, stacked cache)
# ---------------------------------------------------------------------------

def _sublayer_decode(p, x, desc: LayerDesc, cfg: ModelConfig, entry, pos):
    acfg = attn_config(cfg)
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if desc.kind == "attn":
        window = cfg.local_window if desc.attn_type == "local" else None
        ring = (cfg.local_ring_cache and desc.attn_type == "local")
        out, ck, cv = attn_mod.decode_attention(
            p["attn"], h, entry["k"], entry["v"], pos, acfg,
            window=window, ring=ring,
        )
        new_entry = {"k": ck, "v": cv}
    else:
        out, new_entry = ssm_mod.ssm_decode_step(p["mamba"], h, entry,
                                                 cfg.ssm)
    if cfg.post_norm:
        out = rmsnorm_apply(p["post_ln1"], out, cfg.norm_eps)
    x = x + out
    if desc.ff != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if desc.ff == "dense":
            out = mlp_apply(p["mlp"], h, act=cfg.act)
        else:
            out, _ = moe_mod.moe_apply(p["moe"], h, cfg.moe, act=cfg.act)
        if cfg.post_norm:
            out = rmsnorm_apply(p["post_ln2"], out, cfg.norm_eps)
        x = x + out
    return x, new_entry


def decode_blocks(stacked_params, x, cfg: ModelConfig, stacked_cache, pos):
    """One token through the stack; returns (x, new_stacked_cache)."""

    def body(h, scanned):
        bp, bc = scanned
        new_bc = {}
        for i, desc in enumerate(cfg.pattern):
            key = f"sub{i}"
            h, ne = _sublayer_decode(bp[key], h, desc, cfg, bc[key], pos)
            new_bc[key] = ne
        return h, new_bc

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (stacked_params, stacked_cache))
    else:
        outs = []
        for i in range(cfg.num_blocks):
            bp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        stacked_params)
            bc = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        stacked_cache)
            x, nc = body(x, (bp, bc))
            outs.append(nc)
        new_cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_cache


# ---------------------------------------------------------------------------
# Cache specs (for dry-run decode shapes: ShapeDtypeStructs, no alloc)
# ---------------------------------------------------------------------------

def sublayer_cache_spec(cfg: ModelConfig, desc: LayerDesc, batch: int,
                        seq: int) -> Dict[str, Any]:
    if desc.kind == "attn":
        cap = seq
        if cfg.local_ring_cache and desc.attn_type == "local":
            cap = min(seq, cfg.local_window)
        kvshape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
        return {
            "k": ParamSpec(kvshape, ("batch", "seq", "kv_heads", "head_dim"),
                           dtype=cfg.compute_dtype, init="zeros"),
            "v": ParamSpec(kvshape, ("batch", "seq", "kv_heads", "head_dim"),
                           dtype=cfg.compute_dtype, init="zeros"),
        }
    shapes = ssm_mod.ssm_cache_shape(cfg.ssm, batch)
    logical = {
        "conv_x": ("batch", "conv", "ssm_inner"),
        "conv_B": ("batch", "conv", None),
        "conv_C": ("batch", "conv", None),
        "h": ("batch", "ssm_inner", "ssm_state", None),
    }
    return {
        k: ParamSpec(v, logical[k], dtype=cfg.compute_dtype, init="zeros")
        for k, v in shapes.items()
    }


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    block = {
        f"sub{i}": sublayer_cache_spec(cfg, d, batch, seq)
        for i, d in enumerate(cfg.pattern)
    }
    return stack_specs(block, cfg.num_blocks)
