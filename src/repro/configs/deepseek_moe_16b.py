"""deepseek-moe-16b — 2 shared + 64 routed top-6 fine-grained MoE
[arXiv:2401.06066]. Layer 0 uses a dense FFN (d_ff 10944), layers 1..27
use the MoE FFN, as in the original model."""

import dataclasses

from repro.models.moe import MoEConfig

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,  # MHA
        head_dim=128,
        d_ff=1408,  # per routed expert
        vocab_size=102400,
        rope_theta=10000.0,
        tie_embeddings=False,
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared=2),
        dense_first_layer=True,
        dense_first_d_ff=10944,
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="moe"),),
        source="arXiv:2401.06066",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=512, dense_first_d_ff=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared=2),
    )
