"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

The 10 assigned architectures plus the paper-side search configurations
(see repro.core). Arch ids use the assignment's hyphenated spelling.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import SHAPES, LayerDesc, ModelConfig, ShapeSpec, shape_applicable

_MODULES: Dict[str, str] = {
    "llama3-405b": "llama3_405b",
    "minitron-8b": "minitron_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma2-2b": "gemma2_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "dbrx-132b": "dbrx_132b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-370m": "mamba2_370m",
}

ARCH_IDS: List[str] = list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


__all__ = [
    "ARCH_IDS", "SHAPES", "LayerDesc", "ModelConfig", "ShapeSpec",
    "get_config", "get_smoke_config", "shape_applicable",
]
