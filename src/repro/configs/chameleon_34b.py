"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818].

Early fusion means VQ image tokens share the 65536-entry vocabulary with
text tokens, so the backbone consumes plain token ids; the image tokenizer
frontend is a stub per the assignment.
"""

import dataclasses

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        rope_theta=10000.0,
        tie_embeddings=False,
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="dense"),),
        source="arXiv:2405.09818",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
    )
