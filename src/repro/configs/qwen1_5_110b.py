"""qwen1.5-110b — dense GQA with QKV bias [hf:Qwen/Qwen1.5 family]."""

import dataclasses

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1000000.0,
        tie_embeddings=False,
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="dense"),),
        source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment)",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
    )
