"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596].

Modality frontend is a STUB per the assignment: the encoder consumes
precomputed audio frame embeddings [B, frames, d_model] supplied by
``input_specs()``; the decoder is a causal text decoder with cross
attention. RoPE replaces the original sinusoidal positions (TPU-native
adaptation, noted in DESIGN.md §7).
"""

import dataclasses

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec-audio",
        num_layers=12,           # decoder layers
        encoder_layers=12,
        is_encdec=True,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        rope_theta=10000.0,
        act="gelu",
        tie_embeddings=True,
        encoder_frames=1024,
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="dense"),),
        source="arXiv:2308.11596",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_frames=16,
    )
