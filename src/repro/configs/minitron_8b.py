"""minitron-8b — width-pruned nemotron dense GQA [arXiv:2407.14679]."""

import dataclasses

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256000,
        rope_theta=500000.0,
        tie_embeddings=False,
        act="silu",
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="dense"),),
        source="arXiv:2407.14679",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
    )
