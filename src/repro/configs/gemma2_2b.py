"""gemma2-2b — local/global alternating attention, logit softcaps
[arXiv:2408.00118]."""

import dataclasses

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        rope_theta=10000.0,
        local_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        query_scale=256.0 ** -0.5,
        act="gelu",
        tie_embeddings=True,
        embed_scale=True,
        post_norm=True,
        pattern=(
            LayerDesc(kind="attn", attn_type="local", ff="dense"),
            LayerDesc(kind="attn", attn_type="global", ff="dense"),
        ),
        source="arXiv:2408.00118",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, local_window=8,
        query_scale=16.0 ** -0.5,
    )
