"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 with MoE [arXiv:2403.19887].

Block pattern (period 8, matching attn_layer_period=8 / offset=4 and
expert_layer_period=2 / offset=1 of the released model): mamba at indices
{0,2,3,5,6,7}, attention at index 4, MoE FFN at odd indices, dense FFN at
even indices. The Mamba layers use the SSD formulation (TPU-native
adaptation of the paper's Mamba-1 kernels, DESIGN.md §7) with d_state=16.
"""

import dataclasses

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig

from .base import LayerDesc, ModelConfig


def _pattern():
    descs = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        ff = "moe" if i % 2 == 1 else "dense"
        descs.append(LayerDesc(kind=kind, attn_type="global", ff=ff))
    return tuple(descs)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        rope_theta=10000.0,
        tie_embeddings=False,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
        ssm=SSMConfig(d_model=4096, d_state=16, d_conv=4, expand=2,
                      head_dim=64, n_groups=1, chunk=256),
        pattern=_pattern(),
        source="arXiv:2403.19887",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        ssm=SSMConfig(d_model=64, d_state=16, d_conv=4, expand=2,
                      head_dim=16, n_groups=1, chunk=16),
    )
