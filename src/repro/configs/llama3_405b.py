"""llama3-405b — dense GQA transformer, 128k vocab [arXiv:2407.21783]."""

import dataclasses

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=500000.0,
        tie_embeddings=False,
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="dense"),),
        source="arXiv:2407.21783",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
    )
