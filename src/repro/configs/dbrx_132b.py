"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""

import dataclasses

from repro.models.moe import MoEConfig

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,  # per-expert
        vocab_size=100352,
        rope_theta=500000.0,
        tie_embeddings=False,
        moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
        pattern=(LayerDesc(kind="attn", attn_type="global", ff="moe"),),
        source="hf:databricks/dbrx-base",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    )
