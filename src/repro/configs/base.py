"""Unified architecture configuration for all 10 assigned architectures.

A single :class:`ModelConfig` describes dense, MoE, hybrid (attn+mamba),
pure-SSM, and encoder-decoder families. Layer heterogeneity (gemma2's
local/global alternation, jamba's 1:7 attn:mamba interleave with MoE every
other layer, deepseek's dense first layer) is expressed as a *block
pattern*: a tuple of LayerDesc cycled over depth; the transformer stacks
parameters per block and `lax.scan`s over blocks so HLO size stays O(1) in
depth.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str = "attn"  # 'attn' | 'mamba'
    attn_type: str = "global"  # 'global' | 'local'
    ff: str = "dense"  # 'dense' | 'moe' | 'none'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec-audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    local_window: int = 4096
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    attn_chunk_q: int = 512
    attn_dense_threshold: int = 2048
    # ff / moe
    act: str = "silu"
    moe: Optional[MoEConfig] = None
    # hybrid / ssm
    ssm: Optional[SSMConfig] = None
    # block pattern (cycled); overrides simple defaults when set
    pattern: Tuple[LayerDesc, ...] = (LayerDesc(),)
    dense_first_layer: bool = False  # deepseek-moe: layer 0 uses dense FF
    dense_first_d_ff: int = 0
    # encoder-decoder (audio stub frontend provides frame embeddings)
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_frames: int = 1024  # stub frame count for shape specs
    # embeddings
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma: x *= sqrt(d_model)
    # norms
    norm_eps: float = 1e-6
    post_norm: bool = False  # gemma2: additional post-sublayer norms
    # dtypes / execution
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat_policy: str = "nothing_saveable"  # nothing_saveable|dots|none
    # sequence parallelism (Korthikanti et al.): carry the residual
    # stream sharded over ('model' x seq); norms/elementwise run local,
    # TP output all-reduces become reduce-scatters + a gather before
    # each mixer. §Perf iteration for collective-bound train cells.
    sequence_parallel: bool = False
    # ring-buffer KV for local-attention layers: cache capacity =
    # window instead of seq. §Perf iteration for long-context decode.
    local_ring_cache: bool = False
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        body = self.num_layers - (1 if self.dense_first_layer else 0)
        if body % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: {body} body layers not divisible by "
                f"pattern period {len(self.pattern)}"
            )

    @property
    def num_blocks(self) -> int:
        body = self.num_layers - (1 if self.dense_first_layer else 0)
        return body // len(self.pattern)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def attention_free(self) -> bool:
        return all(d.kind != "attn" for d in self.pattern)

    @property
    def pure_full_attention(self) -> bool:
        """True when every layer is global full attention (no SSM/local)."""
        return all(
            d.kind == "attn" and d.attn_type == "global"
            for d in self.pattern
        )

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility: sub-quadratic / bounded-window stacks."""
        return not self.pure_full_attention

    def param_count(self) -> int:
        from repro.models import model as _model

        from repro.models.params import param_count

        return param_count(_model.model_specs(self))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k+shared of num_experts)."""
        from repro.models import model as _model
        from repro.models.params import is_spec, param_count
        import jax

        specs = _model.model_specs(self)
        if self.moe is None:
            return param_count(specs)
        total = 0
        active_frac = (self.moe.top_k) / self.moe.num_experts

        def visit(path, leaf):
            nonlocal total
            if not is_spec(leaf):
                return
            if "experts" in str(leaf.logical):
                total += int(leaf.size * active_frac)
            else:
                total += leaf.size

        jax.tree_util.tree_map_with_path(visit, specs, is_leaf=is_spec)
        return total


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set; the same 4 for every LM arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(applicable, reason-if-not). Encodes the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention architecture: 500k decode "
                       "requires sub-quadratic attention (skip per "
                       "assignment; see DESIGN.md)")
    return True, ""
