"""mamba2-370m — attention-free SSD stack [arXiv:2405.21060]."""

import dataclasses

from repro.models.ssm import SSMConfig

from .base import LayerDesc, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        ssm=SSMConfig(d_model=1024, d_state=128, d_conv=4, expand=2,
                      head_dim=64, n_groups=1, chunk=256),
        pattern=(LayerDesc(kind="mamba", ff="none"),),
        source="arXiv:2405.21060",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, vocab_size=512,
        ssm=SSMConfig(d_model=64, d_state=16, d_conv=4, expand=2,
                      head_dim=16, n_groups=1, chunk=16),
    )
