"""Request batching for serving: buckets, deadlines, graceful degrade.

A lightweight continuous-batching front end: requests are bucketed by
prompt length (power-of-two buckets keep compiled shapes bounded), each
bucket drains as a uniform batch, and a per-request deadline maps onto
the paper's taxonomy for the retrieval-augmented path — if the deadline
budget is short, retrieval degrades from epsilon-guaranteed search to
ng(nprobe), which is precisely the paper's observation that the first
best-so-far answers are near-exact (Fig. 8). That makes load shedding a
*quality* knob rather than a drop decision.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.guarantees import Guarantee


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    deadline_ms: Optional[float] = None
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)


def bucket_of(length: int, min_bucket: int = 16) -> int:
    b = min_bucket
    while b < length:
        b *= 2
    return b


def guarantee_for_deadline(
    deadline_ms: Optional[float], *, full_budget_ms: float = 50.0,
    nprobe_floor: int = 1, nprobe_ceil: int = 64,
    epsilon: float = 0.0,
) -> Guarantee:
    """Map a latency budget onto the taxonomy (graceful degradation)."""
    if deadline_ms is None or deadline_ms >= full_budget_ms:
        return Guarantee(epsilon=epsilon)
    frac = max(deadline_ms, 1e-3) / full_budget_ms
    nprobe = int(round(nprobe_floor
                       + frac * (nprobe_ceil - nprobe_floor)))
    return Guarantee(nprobe=max(nprobe_floor, nprobe))


class Scheduler:
    """Length-bucketed FIFO batching."""

    def __init__(self, max_batch: int = 8, min_bucket: int = 16):
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.queues: Dict[int, List[Request]] = defaultdict(list)
        self.completed: Dict[int, np.ndarray] = {}

    def submit(self, req: Request):
        self.queues[bucket_of(len(req.prompt), self.min_bucket)].append(req)

    def next_batch(self) -> Optional[Tuple[int, List[Request]]]:
        for bucket, q in sorted(self.queues.items()):
            if q:
                take = q[: self.max_batch]
                self.queues[bucket] = q[len(take):]
                return bucket, take
        return None

    def pad_prompts(self, bucket: int, reqs: List[Request]) -> np.ndarray:
        out = np.zeros((len(reqs), bucket), np.int32)
        for i, r in enumerate(reqs):
            out[i, bucket - len(r.prompt):] = r.prompt  # left-pad
        return out
