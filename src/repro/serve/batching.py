"""Request batching for serving: buckets, deadlines, graceful degrade.

A lightweight continuous-batching front end: requests are bucketed by
prompt length (power-of-two buckets keep compiled shapes bounded), each
bucket drains as a uniform batch, and a per-request deadline maps onto
the paper's FULL guarantee taxonomy for the retrieval path
(:func:`guarantee_for_deadline`): a relaxed deadline gets the
deterministic epsilon guarantee, a moderate one degrades to the
probabilistic delta-epsilon tier (the paper's Fig. 8 regime — almost
always exact, bounded failure probability), and a tight one to
ng(nprobe) — precisely the paper's observation that the first
best-so-far answers are near-exact. That makes load shedding a
*quality* knob rather than a drop decision.

The retrieval front (:meth:`Scheduler.run_retrieval`) drives
``DistributedEngine.query`` — resident or out-of-core over spilled
shards, the engine decides — one query batch per guarantee group:
requests drained together but carrying different deadlines are
partitioned by their mapped guarantee (``retrieval_groups``), each
group padded to a power-of-two lane bucket so compiled batch shapes
stay bounded exactly like the prompt buckets.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.guarantees import Guarantee


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    deadline_ms: Optional[float] = None
    # retrieval query in the engine's series space ([n] float); None =
    # this request wants no retrieval
    series: Optional[np.ndarray] = None
    # stamped on obs.now — THE one monotonic clock of the serving
    # stack (launch/serve.py subtracts it from the same clock for
    # queue-wait; mixing time.monotonic here with time.perf_counter
    # there made that subtraction incoherent)
    submitted_at: float = dataclasses.field(default_factory=obs.now)


def bucket_of(length: int, min_bucket: int = 16) -> int:
    b = min_bucket
    while b < length:
        b *= 2
    return b


def guarantee_for_deadline(
    deadline_ms: Optional[float], *, full_budget_ms: float = 50.0,
    delta_budget_frac: float = 0.5, nprobe_floor: int = 1,
    nprobe_ceil: int = 64, epsilon: float = 0.0,
    degraded_delta: float = 0.99, degraded_epsilon: float = 1.0,
) -> Guarantee:
    """Map a latency budget onto the paper's taxonomy (graceful
    degradation across ALL THREE knobs):

      deadline >= full budget (or none)   epsilon-guaranteed
                                          Guarantee(epsilon=epsilon)
      >= delta_budget_frac * full         delta-epsilon: probabilistic
                                          (degraded_delta,
                                          max(epsilon,
                                          degraded_epsilon))
      below that                          ng(nprobe), nprobe scaled
                                          linearly with the remaining
                                          fraction of the delta budget

    Every tier still returns an answer — the paper's Fig. 8 point that
    the first best-so-far is already near-exact is what makes the
    bottom tier acceptable."""
    if deadline_ms is None or deadline_ms >= full_budget_ms:
        return Guarantee(epsilon=epsilon)
    frac = max(deadline_ms, 1e-3) / full_budget_ms
    if frac >= delta_budget_frac:
        return Guarantee(delta=degraded_delta,
                         epsilon=max(epsilon, degraded_epsilon))
    sub = frac / delta_budget_frac
    nprobe = int(round(nprobe_floor
                       + sub * (nprobe_ceil - nprobe_floor)))
    return Guarantee(nprobe=max(nprobe_floor, nprobe))


def remaining_budget_ms(r: Request, at: float) -> Optional[float]:
    """The deadline budget a request has LEFT at time ``at`` (an
    ``obs.now`` stamp): ``deadline_ms`` minus the queue wait already
    spent. None (no deadline) stays None; a fully-spent budget clamps
    to ~0 and maps to the bottom ng tier instead of going negative."""
    if r.deadline_ms is None:
        return None
    waited_ms = (at - r.submitted_at) * 1e3
    return max(r.deadline_ms - waited_ms, 1e-3)


def retrieval_groups(
    reqs: Sequence[Request], at: Optional[float] = None, **gkw,
) -> List[Tuple[Guarantee, List[Request]]]:
    """Partition a drained batch by its deadline-mapped guarantee
    (insertion-ordered, deterministic): the engine takes ONE guarantee
    per query batch, so mixed-deadline batches fan out into one
    engine call per distinct guarantee.

    ``at`` (an ``obs.now`` stamp) switches the mapping from the
    SUBMITTED deadline to the budget REMAINING at drain time: a
    request that already burned 40ms of a 50ms budget in the queue
    maps from the 10ms it has left, not the tier it could have hit had
    it drained instantly. The drain loops pass their drain timestamp;
    the default (None) keeps this function pure for callers that want
    the submitted-deadline partition."""
    groups: Dict[Guarantee, List[Request]] = {}
    for r in reqs:
        budget = (r.deadline_ms if at is None
                  else remaining_budget_ms(r, at))
        g = guarantee_for_deadline(budget, **gkw)
        groups.setdefault(g, []).append(r)
    return list(groups.items())


class Scheduler:
    """Length-bucketed FIFO batching + the deadline-aware retrieval
    front.

    Queue state is lock-guarded (checked guarded_by annotations,
    docs/ANALYSIS.md): the async-serving ROADMAP item has submitters
    and the drain loop on different threads, so submit/next_batch must
    already be safe to interleave."""

    def __init__(self, max_batch: int = 8, min_bucket: int = 16):
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self._lock = threading.Lock()
        self.queues: Dict[int, List[Request]] = \
            defaultdict(list)                     # guarded_by: _lock
        self.completed: Dict[int, np.ndarray] = {}  # guarded_by: _lock

    def submit(self, req: Request):
        bucket = bucket_of(len(req.prompt), self.min_bucket)
        with self._lock:
            self.queues[bucket].append(req)

    def next_batch(self) -> Optional[Tuple[int, List[Request]]]:
        """Drain up to ``max_batch`` requests from the bucket whose HEAD
        request has waited longest. Draining buckets in sorted-key
        order (the old policy) starves large prompts: under sustained
        small-request load the smallest bucket never empties, so a
        request in a bigger bucket waits forever. Oldest-head-first is
        FIFO across buckets (each bucket is FIFO internally), so every
        bucket drains within one max_batch round of its head's turn."""
        with self._lock:
            best = None
            for bucket, q in self.queues.items():
                if q and (best is None
                          or q[0].submitted_at
                          < self.queues[best][0].submitted_at):
                    best = bucket
            if best is None:
                return None
            q = self.queues[best]
            take = q[: self.max_batch]
            self.queues[best] = q[len(take):]
            return best, take

    def pad_prompts(self, bucket: int, reqs: List[Request]) -> np.ndarray:
        out = np.zeros((len(reqs), bucket), np.int32)
        for i, r in enumerate(reqs):
            out[i, bucket - len(r.prompt):] = r.prompt  # left-pad
        return out

    # ---------------------------------------------- retrieval front
    def run_retrieval(
        self, engine, reqs: Sequence[Request], k: int, **gkw,
    ) -> Dict[int, Dict[str, Any]]:
        """Drive ``engine.query`` for a drained batch: group requests
        by their deadline-mapped guarantee (:func:`retrieval_groups`),
        pad each group's query lanes to a power-of-two bucket
        (duplicating the last row — extra lanes are discarded; bounds
        the compiled/retraced batch shapes), and issue one engine call
        per group. Requests without a ``series`` are skipped. Returns
        {uid: {ids, dists, guarantee, kind, retrieval_ms}} —
        ``retrieval_ms`` is the request's OWN guarantee group's engine
        time (each group is timed to completion separately), so
        per-request latency attribution never charges a request for
        another group's work. Group times also land in the registry
        as ``serve.retrieval_ms{kind=...}`` histograms.

        Guarantees are mapped from the budget REMAINING at drain time
        (``retrieval_groups(..., at=drain_stamp)``): queue wait spends
        the deadline, so a request that waited 40ms of a 50ms budget
        gets the tier its 10ms can still honor."""
        import jax.numpy as jnp

        out: Dict[int, Dict[str, Any]] = {}
        drained_at = obs.now()
        for g, group in retrieval_groups(
                [r for r in reqs if r.series is not None],
                at=drained_at, **gkw):
            qs = np.stack([np.asarray(r.series, np.float32)
                           for r in group])
            lanes = bucket_of(qs.shape[0], 1)
            if lanes > qs.shape[0]:
                qs = np.concatenate(
                    [qs, np.repeat(qs[-1:], lanes - qs.shape[0], 0)])
            with obs.span("serve.retrieval_group", kind=g.kind,
                          lanes=lanes, requests=len(group)):
                t0 = obs.now()
                res = engine.query(jnp.asarray(qs), k, g)
                # host copies block on the device result, so the group
                # time covers the full engine call
                ids_np = np.asarray(res.ids)
                dists_np = np.asarray(res.dists)
                group_ms = (obs.now() - t0) * 1e3
            obs.REGISTRY.histogram(
                "serve.retrieval_ms", kind=g.kind).record(group_ms)
            # fault-tolerant degrade (docs/FAULT.md): if the engine
            # lost shards past retries and replicas, the answer's
            # honest guarantee is delta-epsilon with the recomputed
            # effective_delta — surface that per request instead of
            # echoing the requested tier. Stats travel ON the result
            # (QueryResult.stats): reading mutable engine state here
            # misattributed degradation the moment lane workers ran
            # query() concurrently. getattr tolerates plain
            # SearchResult from stub engines in tests.
            stats = getattr(res, "stats", None)
            degraded = bool(stats is not None and stats.degraded)
            kind = "delta-epsilon" if degraded else g.kind
            if degraded:
                obs.REGISTRY.counter(
                    "serve.degraded", kind=g.kind).inc(len(group))
            for i, r in enumerate(group):
                entry: Dict[str, Any] = {
                    "ids": ids_np[i],
                    "dists": dists_np[i],
                    "guarantee": g,
                    "kind": kind,
                    "retrieval_ms": group_ms,
                    "stats": stats,
                }
                if degraded:
                    entry["degraded"] = True
                    entry["requested_kind"] = g.kind
                    entry["effective_delta"] = float(
                        stats.effective_delta)
                    entry["shards_lost"] = int(stats.shards_lost)
                out[r.uid] = entry
        return out
