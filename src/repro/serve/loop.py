"""Continuous-batching serving front: per-guarantee lanes, no barrier.

The static front (:func:`repro.launch.serve.serve_requests`) drains
one batch, answers it to completion, then drains the next — a global
barrier: a cheap ng query drained alongside an expensive epsilon group
waits for the whole round. :class:`ServeFront` replaces that with the
refill-as-you-finish idiom from modern inference stacks (the maxtext
continuous-batching loop the ROADMAP cites):

  lanes     requests are routed by their NOMINAL guarantee kind
            (mapped from the submitted deadline) into one of three
            lanes — ``epsilon`` (also hosting ``exact``),
            ``delta-epsilon``, ``ng``. Each lane has its own worker
            thread draining up to ``max_batch`` requests at a time, so
            an expensive epsilon batch in flight never blocks the ng
            lane from refilling — the barrier is gone.
  remap     at DRAIN time each request's guarantee is recomputed from
            its remaining deadline budget
            (:func:`repro.serve.batching.retrieval_groups` with
            ``at=drain_stamp``): queue wait spends the budget, so the
            tier a request gets is the tier its remaining time can
            honor.
  shed      while the :class:`repro.serve.admission.AdmissionController`
            reports sustained pressure, each drained group is degraded
            one further tier (quality knob, not a drop — docs/SERVING.md).
  admission past the depth cap, submit() rejects with a reason instead
            of queueing into a guaranteed deadline miss.

Each engine call is one ``engine.query`` per (lane-batch x remapped
guarantee) group, lanes padded to a power of two exactly like the
static front. Concurrent calls are safe and bit-exact vs serial
execution: stats travel on the result (``QueryResult.stats``), and
per-shard cache state is serialized by the engine's per-copy locks
(core/engine.py) — the re-entrancy contract this front forced.

Thread-safety: lane deques are guarded by one condition
(``# guarded_by: _cond``); completion is per-ticket (an Event), so
submitters wait on their own request only. Lock order: the front's
condition is released BEFORE ``engine.query`` runs, so front-lock ->
engine-lock edges never form while a worker holds the condition —
``obs.lockorder`` verifies acyclicity in the stress test.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.guarantees import Guarantee

from .admission import AdmissionController
from .batching import (Request, bucket_of, guarantee_for_deadline,
                       retrieval_groups)

__all__ = ["LANES", "Rejected", "ServeFront", "Ticket", "WRITE_LANE",
           "lane_of"]

LANES = ("epsilon", "delta-epsilon", "ng")
# the WRITE lane (docs/INGEST.md): mutations ride their own worker so
# a burst of inserts never queues behind an expensive epsilon batch —
# and vice versa. Writes are O(rows) memtable updates (store/delta.py),
# not engine queries, so the lane needs no admission slot: admission
# protects retrieval deadlines, which writes cannot miss.
WRITE_LANE = "write"


def lane_of(kind: str) -> str:
    """Lane routing: ``exact`` rides the ``epsilon`` lane (same cost
    regime — guarantee-driven visits), the other kinds get their own."""
    return "epsilon" if kind == "exact" else kind


class Rejected(RuntimeError):
    """submit() refused by admission control; ``reason`` says why."""

    def __init__(self, reason: str):
        super().__init__(f"request rejected: {reason}")
        self.reason = reason


class Ticket:
    """A submitted request's completion handle: ``result()`` blocks
    until the lane worker answers (or errors), then returns the entry
    dict ({ids, dists, kind, guarantee, retrieval_ms, queue_wait_ms,
    latency_ms, done_at, ...} — or {"error": ...})."""

    __slots__ = ("uid", "_event", "_entry")

    def __init__(self, uid: int):
        self.uid = uid
        self._event = threading.Event()
        self._entry: Optional[Dict[str, Any]] = None

    def done(self) -> bool:
        return self._event.is_set()

    def _complete(self, entry: Dict[str, Any]) -> None:
        self._entry = entry
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.uid} not answered within {timeout}s")
        assert self._entry is not None
        return self._entry


class ServeFront:
    """The continuous-batching retrieval front (module docstring).

    Construct over a built engine, ``start()`` (or use as a context
    manager), ``submit(Request)`` from any number of threads, read
    answers via the returned :class:`Ticket`. ``stop(drain=True)``
    answers everything queued before returning; ``drain=False``
    completes pending tickets with an error entry instead.

    ``lock_recorder`` (an ``obs.LockOrderRecorder``) wraps the front's
    condition lock so stress tests can assert the full lane+engine
    lock graph stays acyclic.
    """

    def __init__(self, engine, k: int = 5, *, max_batch: int = 8,
                 admission: Optional[AdmissionController] = None,
                 guarantee_kw: Optional[dict] = None,
                 lock_recorder=None):
        self.engine = engine
        self.k = k
        self.max_batch = max_batch
        self.admission = admission or AdmissionController()
        self.gkw = dict(guarantee_kw or {})
        lock: Any = threading.RLock()
        if lock_recorder is not None:
            lock = lock_recorder.wrap(lock, "serve.front._cond")
        self._cond = threading.Condition(lock)
        self._lanes: Dict[str, deque] = {
            ln: deque()
            for ln in LANES + (WRITE_LANE,)}          # guarded_by: _cond
        self._stopping = False                        # guarded_by: _cond
        self._drain_on_stop = True                    # guarded_by: _cond
        self._workers: List[threading.Thread] = []

    # ---------------------------------------------------- lifecycle
    def start(self) -> "ServeFront":
        if self._workers:
            return self
        for ln in LANES + (WRITE_LANE,):
            t = threading.Thread(target=self._worker, args=(ln,),
                                 name=f"serve-lane-{ln}", daemon=True)
            self._workers.append(t)
            t.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the lane workers. ``drain=True`` (default) answers
        every queued request first; ``drain=False`` fails pending
        tickets with an ``{"error": "stopped"}`` entry."""
        with self._cond:
            self._stopping = True
            self._drain_on_stop = drain
            self._cond.notify_all()
        for t in self._workers:
            t.join()
        self._workers = []

    def __enter__(self) -> "ServeFront":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    # ------------------------------------------------------- submit
    def submit(self, req: Request) -> Ticket:
        """Admit + enqueue one request; raises :class:`Rejected` past
        the admission cap. Safe from any thread."""
        kind = guarantee_for_deadline(req.deadline_ms, **self.gkw).kind
        reason = self.admission.try_admit(kind)
        if reason is not None:
            raise Rejected(reason)
        ticket = Ticket(req.uid)
        with self._cond:
            if self._stopping:
                self.admission.release()
                raise Rejected("stopped")
            self._lanes[lane_of(kind)].append((req, ticket))
            self._cond.notify_all()
        return ticket

    def submit_write(self, op: str, rows=None, ids=None,
                     uid: int = -1) -> Ticket:
        """Enqueue one mutation on the write lane (docs/INGEST.md):
        ``op='insert'`` with ``rows`` (optionally ``ids``), or
        ``op='delete'`` with ``ids``. Returns a :class:`Ticket` whose
        entry reports the assigned global ids and the ``applied_at``
        stamp — the instant the rows became retrievable, which the
        freshness metric (benchmarks/bench_serve_load.py) measures
        against. Safe from any thread; writes skip admission (module
        constant rationale)."""
        if op not in ("insert", "delete"):
            raise ValueError(f"op must be 'insert'|'delete', got {op!r}")
        if op == "insert" and rows is None:
            raise ValueError("insert needs rows")
        if op == "delete" and ids is None:
            raise ValueError("delete needs ids")
        ticket = Ticket(uid)
        with self._cond:
            if self._stopping:
                raise Rejected("stopped")
            self._lanes[WRITE_LANE].append(
                ((op, rows, ids, obs.now()), ticket))
            self._cond.notify_all()
        return ticket

    # -------------------------------------------------------- drain
    def _take(self, lane: str) -> Optional[List[Tuple[Request, Ticket]]]:
        """Block until this lane has work (or the front stops).
        Returns up to ``max_batch`` entries, or None to exit."""
        with self._cond:
            q = self._lanes[lane]
            while not q and not self._stopping:
                self._cond.wait()
            if not q:
                return None           # stopping and (drained or not)
            if self._stopping and not self._drain_on_stop:
                batch = list(q)
                q.clear()
                for _r, t in batch:
                    t._complete({"error": "stopped"})
                if lane != WRITE_LANE:  # writes hold no admission slot
                    self.admission.release(len(batch))
                return None
            batch = [q.popleft() for _ in range(min(len(q),
                                                    self.max_batch))]
            return batch

    def _worker(self, lane: str) -> None:
        while True:
            batch = self._take(lane)
            if batch is None:
                return
            obs.REGISTRY.histogram(
                "serve.lane.batch_size", lane=lane).record(len(batch))
            try:
                if lane == WRITE_LANE:
                    self._process_writes(batch)
                else:
                    self._process(batch)
            except Exception as e:  # noqa: BLE001 — a lane worker must outlive any single batch: complete its tickets with the error and keep serving
                obs.REGISTRY.counter(
                    "serve.loop.errors", lane=lane).inc()
                for _r, t in batch:
                    if not t.done():
                        t._complete({"error": repr(e)})
            finally:
                if lane != WRITE_LANE:  # writes hold no admission slot
                    self.admission.release(len(batch))

    def _process_writes(self, batch) -> None:
        """Apply one drained write-lane batch in submission order:
        ``engine.insert`` / ``engine.delete`` are O(rows) memtable
        updates (store/delta.py), so the write lane stays cheap and
        never holds a retrieval lane's resources. The completion entry
        carries ``applied_at`` — from that instant the next query()
        snapshot sees the mutation (freshness, docs/INGEST.md)."""
        for (op, rows, ids, submitted), t in batch:
            t0 = obs.now()
            if op == "insert":
                out_ids = np.asarray(self.engine.insert(rows, ids))
                n = int(out_ids.shape[0])
            else:
                out_ids = np.asarray(ids, np.int64).reshape(-1)
                self.engine.delete(out_ids)
                n = int(out_ids.shape[0])
            done = obs.now()
            obs.REGISTRY.counter("serve.writes", op=op).inc(n)
            t._complete({
                "op": op, "ids": out_ids, "applied_at": done,
                "queue_wait_ms": max((t0 - submitted) * 1e3, 0.0),
                "latency_ms": max((done - submitted) * 1e3, 0.0),
                "done_at": done,
            })

    def _process(self, batch: List[Tuple[Request, Ticket]]) -> None:
        """Answer one drained lane batch: remap guarantees from the
        REMAINING deadline budget, degrade one tier under shedding,
        then one engine call per resulting guarantee group."""
        import jax.numpy as jnp

        drained_at = obs.now()
        tickets = {r.uid: t for r, t in batch}
        no_series = [r for r, _t in batch if r.series is None]
        for r in no_series:
            # nothing to retrieve — answer immediately (the decode
            # path, if any, is the caller's business)
            tickets[r.uid]._complete({
                "ids": None, "dists": None,
                "kind": guarantee_for_deadline(
                    r.deadline_ms, **self.gkw).kind,
                "retrieval_ms": 0.0,
                "queue_wait_ms": max(
                    (drained_at - r.submitted_at) * 1e3, 0.0),
                "latency_ms": max(
                    (obs.now() - r.submitted_at) * 1e3, 0.0),
                "done_at": obs.now(),
            })
        shedding = self.admission.shedding()
        groups = retrieval_groups(
            [r for r, _t in batch if r.series is not None],
            at=drained_at, **self.gkw)
        for g, group in groups:
            g_final = self.admission.shed(g) if shedding else g
            self._query_group(jnp, g, g_final, group, tickets,
                              drained_at, shed=shedding
                              and g_final != g)

    def _query_group(self, jnp, g_nominal: Guarantee, g: Guarantee,
                     group: List[Request],
                     tickets: Dict[int, Ticket], drained_at: float,
                     *, shed: bool) -> None:
        qs = np.stack([np.asarray(r.series, np.float32)
                       for r in group])
        lanes = bucket_of(qs.shape[0], 1)
        if lanes > qs.shape[0]:
            qs = np.concatenate(
                [qs, np.repeat(qs[-1:], lanes - qs.shape[0], 0)])
        with obs.span("serve.retrieval_group", kind=g.kind,
                      lanes=lanes, requests=len(group)):
            t0 = obs.now()
            res = self.engine.query(jnp.asarray(qs), self.k, g)
            ids_np = np.asarray(res.ids)
            dists_np = np.asarray(res.dists)
            group_ms = (obs.now() - t0) * 1e3
        obs.REGISTRY.histogram(
            "serve.retrieval_ms", kind=g.kind).record(group_ms)
        # honest tier accounting, same as the static front: a shard
        # lost past retries/replicas degrades the ANSWER's guarantee
        # (docs/FAULT.md) — stats ride the result, never engine state
        stats = getattr(res, "stats", None)
        degraded = bool(stats is not None and stats.degraded)
        kind = "delta-epsilon" if degraded else g.kind
        if degraded:
            obs.REGISTRY.counter(
                "serve.degraded", kind=g.kind).inc(len(group))
        done_at = obs.now()
        for i, r in enumerate(group):
            entry: Dict[str, Any] = {
                "ids": ids_np[i],
                "dists": dists_np[i],
                "guarantee": g,
                "kind": kind,
                "nominal_kind": g_nominal.kind,
                "retrieval_ms": group_ms,
                "queue_wait_ms": max(
                    (drained_at - r.submitted_at) * 1e3, 0.0),
                "latency_ms": max(
                    (done_at - r.submitted_at) * 1e3, 0.0),
                "done_at": done_at,
                "stats": stats,
            }
            if shed:
                entry["shed"] = True
            if degraded:
                entry["degraded"] = True
                entry["requested_kind"] = g.kind
                entry["effective_delta"] = float(stats.effective_delta)
                entry["shards_lost"] = int(stats.shards_lost)
            tickets[r.uid]._complete(entry)
