"""Serving: prefill + decode step builders and a generate loop.

`build_decode_step` is the function the decode-shape dry-runs lower:
one token through the stack against a fixed-capacity cache. Sampling is
greedy or temperature-categorical. `generate` drives prefill -> N decode
steps (used by examples and integration tests); cache capacity is
allocated up front and prefill writes the prefix.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def build_decode_step(cfg: ModelConfig, *, sample: str = "greedy",
                      temperature: float = 1.0):
    def decode_step(params, tokens, cache, pos, rng=None):
        logits, cache = model_mod.decode_step(params, tokens, cache, pos,
                                              cfg)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)
        else:
            nxt = jax.random.categorical(
                rng, logits[:, -1, :] / temperature)
        return nxt.astype(jnp.int32), logits, cache

    return decode_step


def _grow_cache(cache, capacity: int):
    """Pad prefill KV extents to `capacity` along the seq axis."""

    def grow(x):
        # KV tensors are [..., S, kv, hd] stacked as [G, B, S, kv, hd];
        # ssm states have no seq axis — identified by ndim/name shape.
        if x.ndim >= 4 and x.shape[-3] < capacity:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, capacity - x.shape[-3])
            return jnp.pad(x, pad)
        return x

    def is_kv(path):
        last = str(path[-1].key) if path else ""
        return last in ("k", "v")

    out = jax.tree_util.tree_map_with_path(
        lambda p, x: grow(x) if is_kv(p) else x, cache)
    return out


def generate(
    params, cfg: ModelConfig, prompt: jax.Array, n_steps: int,
    *, sample: str = "greedy", rng=None, frames: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """prompt [B, S] -> generated tokens [B, n_steps]."""
    b, s = prompt.shape
    batch = {"tokens": prompt}
    if cfg.is_encdec:
        assert frames is not None
        batch["frames"] = frames
    logits, cache = model_mod.prefill(params, batch, cfg)
    cache = _grow_cache(cache, s + n_steps)
    step_fn = build_decode_step(cfg, sample=sample)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    toks = [tok]
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    for t in range(n_steps - 1):
        rng, sub = jax.random.split(rng)
        tok, _, cache = step_fn(
            params, tok[:, None], cache, jnp.int32(s + t), sub)
        toks.append(tok)
    return jnp.stack(toks, axis=1), {"cache": cache}
