from . import admission, batching, loop, serve_step

__all__ = ["admission", "batching", "loop", "serve_step"]
