from . import batching, serve_step

__all__ = ["batching", "serve_step"]
