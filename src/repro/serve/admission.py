"""Admission control + QoS load-shedding for the continuous serving
front.

Two independent pressure valves in front of :mod:`repro.serve.loop`,
both making overload an EXPLICIT, observable decision instead of an
unbounded queue:

  admission   a hard cap on in-system depth (queued + in-flight).
              Past the cap a submit is REJECTED with a reason — the
              caller hears "try later" in O(1) instead of joining a
              queue whose wait already guarantees a missed deadline.
              Depth is exported as the ``serve.queue_depth`` gauge;
              accept/reject decisions as
              ``serve.admission.accepted{kind=...}`` /
              ``serve.admission.rejected{reason=...}`` counters.
  shedding    a hysteresis band below the cap. While depth sits above
              ``shed_high`` the controller reports ``shedding()`` and
              the drain loop degrades each drained request ONE
              guarantee tier (epsilon -> delta-epsilon -> ng ->
              halved nprobe, :func:`degrade_tier`); shedding switches
              off only once depth falls below ``shed_low``, so the
              valve doesn't flap at the boundary. Sheds are counted
              per ORIGINAL kind (``serve.admission.shed{kind=...}``).

This is the paper's graceful-degradation story operationalized: Fig. 8
shows the first best-so-far answers are near-exact, so under pressure
the cheapest correct move is to spend less per query (lower tier) and
keep meeting deadlines, rather than to keep the tier and miss them.
The guarantee each response REPORTS is the degraded one — quality is
traded, never silently misreported.

Thread-safety: one mutex guards depth + the shed flag; every method is
safe to call from any submitter or lane-worker thread.
"""

from __future__ import annotations

import threading
from typing import Optional

from repro import obs
from repro.core.guarantees import Guarantee

__all__ = ["AdmissionController", "degrade_tier"]

QUEUE_FULL = "queue_full"


def degrade_tier(g: Guarantee) -> Guarantee:
    """One step down the paper's guarantee lattice (quality knob, not
    a drop decision): epsilon/exact -> delta-epsilon (0.99, eps>=1),
    delta-epsilon -> ng(nprobe=16), ng -> ng with nprobe halved
    (floor 1 — the bottom tier still answers)."""
    kind = g.kind
    if kind in ("exact", "epsilon"):
        return Guarantee(delta=0.99, epsilon=max(g.epsilon, 1.0))
    if kind == "delta-epsilon":
        return Guarantee(nprobe=16)
    return Guarantee(nprobe=max(1, (g.nprobe or 1) // 2))


class AdmissionController:
    """Bounded-depth admission with hysteresis load-shedding.

    ``max_depth`` bounds requests IN THE SYSTEM (admitted and not yet
    released — queued or in flight). ``shed_high`` / ``shed_low`` are
    absolute depths derived from the given fractions of the cap;
    construction validates ``0 <= shed_low <= shed_high <= max_depth``.
    """

    def __init__(self, max_depth: int = 64, *,
                 shed_high_frac: float = 0.75,
                 shed_low_frac: float = 0.25):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        if not 0.0 <= shed_low_frac <= shed_high_frac <= 1.0:
            raise ValueError(
                "need 0 <= shed_low_frac <= shed_high_frac <= 1, got "
                f"{shed_low_frac}, {shed_high_frac}")
        self.max_depth = max_depth
        self.shed_high = max(1, int(round(shed_high_frac * max_depth)))
        self.shed_low = int(round(shed_low_frac * max_depth))
        self._lock = threading.Lock()
        self._depth = 0                           # guarded_by: _lock
        self._shedding = False                    # guarded_by: _lock
        self._gauge = obs.REGISTRY.gauge("serve.queue_depth")

    # ------------------------------------------------------- admit
    def try_admit(self, kind: str = "none") -> Optional[str]:
        """Admit one request (labeled by its nominal guarantee kind
        for the accept counter). Returns None on admit, or the reject
        reason string — currently only ``"queue_full"`` — when the
        system is at ``max_depth``. Each admit must be paired with one
        :meth:`release` when the request leaves the system (completed,
        failed, or dropped at drain)."""
        with self._lock:
            if self._depth >= self.max_depth:
                obs.REGISTRY.counter(
                    "serve.admission.rejected", reason=QUEUE_FULL).inc()
                return QUEUE_FULL
            self._depth += 1
            self._update_locked()
        obs.REGISTRY.counter("serve.admission.accepted", kind=kind).inc()
        return None

    def release(self, n: int = 1) -> None:
        """A request (or n of them) left the system."""
        with self._lock:
            self._depth = max(0, self._depth - n)
            self._update_locked()

    def _update_locked(self) -> None:
        # hysteresis: on above shed_high, off below shed_low, sticky
        # in between. Lexically outside a with-block because BOTH
        # callers (try_admit/release) already hold _lock — the _locked
        # suffix is the calling convention.
        if self._depth >= self.shed_high:  # repro: allow[guarded-by] called with _lock held by both callers (_locked calling convention)
            self._shedding = True  # repro: allow[guarded-by] called with _lock held by both callers (_locked calling convention)
        elif self._depth <= self.shed_low:  # repro: allow[guarded-by] called with _lock held by both callers (_locked calling convention)
            self._shedding = False  # repro: allow[guarded-by] called with _lock held by both callers (_locked calling convention)
        self._gauge.set(self._depth)  # repro: allow[guarded-by] called with _lock held by both callers (_locked calling convention)

    # ------------------------------------------------------- state
    @property
    def depth(self) -> int:
        # repro: allow[guarded-by] lock-free monitoring read: a single int load is GIL-atomic and this sits on submit/bench hot paths
        return self._depth

    def shedding(self) -> bool:
        """True while the drain loop should degrade tiers (hysteresis
        band: latched above ``shed_high``, cleared below
        ``shed_low``)."""
        # repro: allow[guarded-by] lock-free monitoring read: a single bool load is GIL-atomic; staleness by one transition only widens/narrows shedding by one request
        return self._shedding

    def shed(self, g: Guarantee) -> Guarantee:
        """Degrade one tier and count it against the ORIGINAL kind.
        No-op (no counter) when the tier cannot drop further."""
        out = degrade_tier(g)
        if out != g:
            obs.REGISTRY.counter(
                "serve.admission.shed", kind=g.kind).inc()
        return out
