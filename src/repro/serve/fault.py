"""Serving-side fault tolerance: retries, failover, circuit breaking.

The policy layer between the engine's concurrent shard owners and the
raw injection points in :mod:`repro.fault` (docs/FAULT.md):

    RetryPolicy     capped exponential backoff + a per-attempt
                    deadline (a slow shard fails over instead of
                    stalling the whole query).
    CircuitBreaker  consecutive-failure counting per (shard, copy):
                    a copy that keeps failing is skipped without
                    paying its deadline, until a cooldown elapses
                    (half-open: the next attempt probes it again).
    FaultContext    what a single shard-serve attempt threads into
                    the OOC host loop — the injector plus the
                    attempt's absolute deadline, checked cooperatively
                    at every gather/score point (the loop cannot be
                    preempted mid-I/O, so deadlines are polled, not
                    delivered).
    serve_shard_with_failover
                    the attempt loop: owner copy first, then each
                    replica in attempt order, backoff between
                    attempts, ShardLost when every copy is exhausted.

Every event is a registry metric: ``fault.retries`` /
``fault.failovers`` / ``fault.shard_lost`` / ``fault.breaker_open`` /
``fault.breaker_skip`` counters and the ``fault.failover_latency_ms``
histogram (first failure -> eventual success on another copy).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro import obs
from repro.fault import FaultInjected, FaultInjector  # noqa: F401

__all__ = [
    "FaultContext", "FaultInjected", "FaultInjector", "RetryPolicy",
    "CircuitBreaker", "ShardLost", "ShardServeInfo", "ShardTimeout",
    "serve_shard_with_failover",
]


class ShardTimeout(RuntimeError):
    """A shard-serve attempt overran its per-attempt deadline."""


class ShardLost(RuntimeError):
    """Every copy of a shard failed past the retry budget — the query
    must degrade (core/engine recomputes the honest delta)."""

    def __init__(self, shard: int, cause: Optional[BaseException] = None):
        super().__init__(
            f"shard {shard} lost after retries and replicas"
            + (f": {cause!r}" if cause is not None else ""))
        self.shard = shard
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-shard retry/backoff/deadline policy.

    The attempt budget is ``max(max_attempts, n_copies)`` so every
    replica gets at least one shot even under a small retry budget.
    ``attempt_deadline_s`` is the per-ATTEMPT wall budget, checked
    cooperatively at the host loop's gather/score points; ``None``
    disables timeouts (an attempt runs to completion or error).
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.005
    backoff_cap_s: float = 0.25
    attempt_deadline_s: Optional[float] = None

    def backoff_s(self, attempt: int) -> float:
        return min(self.backoff_cap_s,
                   self.backoff_base_s * (2.0 ** attempt))


class CircuitBreaker:
    """Consecutive-failure breaker keyed by (shard, copy dir).

    ``threshold`` consecutive failures open the circuit for
    ``cooldown_s``; while open, ``allow`` is False and the failover
    loop skips the copy without paying its deadline. After the
    cooldown the circuit is half-open: one attempt probes the copy
    and its outcome closes or re-opens it.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._lock = threading.Lock()
        # key -> [consecutive failures, open-until stamp (obs.now)]
        self._state: Dict[object, list] = {}  # guarded by _lock

    def _slot(self, key) -> list:
        return self._state.setdefault(key, [0, 0.0])

    def allow(self, key) -> bool:
        with self._lock:
            return obs.now() >= self._slot(key)[1]

    def is_open(self, key) -> bool:
        return not self.allow(key)

    def record_success(self, key) -> None:
        with self._lock:
            self._state[key] = [0, 0.0]

    def record_failure(self, key) -> None:
        with self._lock:
            slot = self._slot(key)
            slot[0] += 1
            # at/past threshold every further failure re-opens — a
            # failed half-open probe goes straight back to open
            opened = slot[0] >= self.threshold
            if opened:
                slot[1] = obs.now() + self.cooldown_s
        if opened:
            obs.REGISTRY.counter("fault.breaker_open",
                                 key=str(key)).inc()


@dataclasses.dataclass
class FaultContext:
    """Per-attempt context threaded into the OOC host loop via
    ``search_ooc(..., fault=ctx)``: the loop calls ``check(point)``
    before every gather and score, which evaluates the injector's
    rules AND the attempt deadline. ``replica`` is the attempt-order
    position (0 = the copy currently owning the shard)."""

    shard: int
    replica: int = 0
    injector: Optional[FaultInjector] = None
    deadline: Optional[float] = None  # absolute obs.now stamp

    def check(self, point: str) -> None:
        if self.injector is not None:
            self.injector.check(point, shard=self.shard,
                                replica=self.replica)
        if self.deadline is not None and obs.now() > self.deadline:
            raise ShardTimeout(
                f"shard {self.shard} attempt (copy position "
                f"{self.replica}) overran its deadline at "
                f"point {point!r}")


@dataclasses.dataclass
class ShardServeInfo:
    """How one shard's answer was obtained (feeds OocStats)."""

    shard: int
    attempts: int = 1
    retries: int = 0      # failed attempts before the success
    failovers: int = 0    # 1 when served from a non-owner copy
    served_dir: str = ""
    served_replica: int = 0  # attempt-order position that served


def serve_shard_with_failover(
    attempt_fn: Callable[[str, FaultContext], object],
    *,
    shard: int,
    replica_dirs: Sequence[str],
    policy: Optional[RetryPolicy] = None,
    breaker: Optional[CircuitBreaker] = None,
    injector: Optional[FaultInjector] = None,
) -> Tuple[object, ShardServeInfo]:
    """Serve one shard with retries and replica failover.

    ``replica_dirs`` is the shard's store copies in attempt order
    (owner first); attempt ``i`` uses copy ``i % len(replica_dirs)``,
    so retries past the copy count wrap back around. Between failed
    attempts the worker sleeps the policy backoff. Returns
    ``(attempt_fn result, ShardServeInfo)``; raises :class:`ShardLost`
    carrying the last cause when every attempt failed.
    """
    if not replica_dirs:
        raise ValueError(f"shard {shard}: no store copies to serve")
    policy = policy or RetryPolicy()
    n_attempts = max(int(policy.max_attempts), len(replica_dirs))
    reg = obs.REGISTRY
    first_failure_t: Optional[float] = None
    cause: Optional[BaseException] = None
    failed = 0
    for attempt in range(n_attempts):
        pos = attempt % len(replica_dirs)
        d = replica_dirs[pos]
        if breaker is not None and not breaker.allow((shard, d)):
            reg.counter("fault.breaker_skip", shard=str(shard)).inc()
            if cause is None:
                cause = RuntimeError(
                    f"circuit open for shard {shard} copy {d!r}")
            continue
        deadline = None
        if policy.attempt_deadline_s is not None:
            deadline = obs.now() + policy.attempt_deadline_s
        ctx = FaultContext(shard=shard, replica=pos,
                           injector=injector, deadline=deadline)
        try:
            ctx.check("shard")  # whole-shard kill gate
            result = attempt_fn(d, ctx)
        # repro: allow[broad-except] failover boundary: ANY attempt failure — injected fault, deadline, I/O error, device error — must mean retry/failover, never propagate past the policy loop (the last cause rides out on ShardLost)
        except Exception as e:
            failed += 1
            cause = e
            if first_failure_t is None:
                first_failure_t = obs.now()
            if breaker is not None:
                breaker.record_failure((shard, d))
            reg.counter("fault.attempt_failed", shard=str(shard)).inc()
            if attempt + 1 < n_attempts:
                reg.counter("fault.retries", shard=str(shard)).inc()
                time.sleep(policy.backoff_s(attempt))
            continue
        if breaker is not None:
            breaker.record_success((shard, d))
        info = ShardServeInfo(shard=shard, attempts=attempt + 1,
                              retries=failed, failovers=int(pos != 0),
                              served_dir=d, served_replica=pos)
        if pos != 0:
            reg.counter("fault.failovers", shard=str(shard)).inc()
        if first_failure_t is not None:
            reg.histogram("fault.failover_latency_ms",
                          shard=str(shard)).record(
                              (obs.now() - first_failure_t) * 1e3)
        return result, info
    reg.counter("fault.shard_lost", shard=str(shard)).inc()
    raise ShardLost(shard, cause)
