"""The paper's guarantee taxonomy (Fig. 1 / Table 1) as a first-class type.

Every query carries a :class:`Guarantee`; every answer reports which
guarantee it satisfies. The lattice (paper §2, Defs 5-7 and §3.3):

    exact            delta=1, epsilon=0, unbounded visits
    epsilon          delta=1, epsilon>0            (deterministic bound)
    delta-epsilon    delta<1, epsilon>=0           (probabilistic bound)
    ng               nprobe-bounded visits         (no guarantee)

Setting delta=1 in a delta-epsilon method yields epsilon-approximate;
additionally epsilon=0 yields exact — Algorithm 2 degenerates to
Algorithm 1 (property-tested in tests/test_guarantees.py).
"""

from __future__ import annotations

from typing import NamedTuple, Optional


class Guarantee(NamedTuple):
    delta: float = 1.0
    epsilon: float = 0.0
    nprobe: Optional[int] = None  # None = guarantee-driven (unbounded)

    @property
    def kind(self) -> str:
        if self.nprobe is not None:
            return "ng"
        if self.delta < 1.0:
            return "delta-epsilon"
        if self.epsilon > 0.0:
            return "epsilon"
        return "exact"

    def validate(self) -> "Guarantee":
        if not (0.0 <= self.delta <= 1.0):
            raise ValueError(f"delta must be in [0,1], got {self.delta}")
        if self.epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {self.epsilon}")
        if self.nprobe is not None and self.nprobe < 1:
            raise ValueError(f"nprobe must be >= 1, got {self.nprobe}")
        return self


EXACT = Guarantee()


def exact() -> Guarantee:
    return EXACT


def epsilon(eps: float) -> Guarantee:
    return Guarantee(epsilon=eps).validate()


def delta_epsilon(delta: float, eps: float = 0.0) -> Guarantee:
    return Guarantee(delta=delta, epsilon=eps).validate()


def ng(nprobe: int = 1) -> Guarantee:
    """Paper's ng-approximate: visit nprobe leaves, keep best-so-far."""
    return Guarantee(nprobe=nprobe).validate()
