"""The paper's guarantee taxonomy (Fig. 1 / Table 1) as a first-class type.

Every query carries a :class:`Guarantee`; every answer reports which
guarantee it satisfies. The lattice (paper §2, Defs 5-7 and §3.3):

    exact            delta=1, epsilon=0, unbounded visits
    epsilon          delta=1, epsilon>0            (deterministic bound)
    delta-epsilon    delta<1, epsilon>=0           (probabilistic bound)
    ng               nprobe-bounded visits         (no guarantee)

Setting delta=1 in a delta-epsilon method yields epsilon-approximate;
additionally epsilon=0 yields exact — Algorithm 2 degenerates to
Algorithm 1 (property-tested in tests/test_guarantees.py).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np


class Guarantee(NamedTuple):
    delta: float = 1.0
    epsilon: float = 0.0
    nprobe: Optional[int] = None  # None = guarantee-driven (unbounded)

    @property
    def kind(self) -> str:
        if self.nprobe is not None:
            return "ng"
        if self.delta < 1.0:
            return "delta-epsilon"
        if self.epsilon > 0.0:
            return "epsilon"
        return "exact"

    def validate(self) -> "Guarantee":
        if not (0.0 <= self.delta <= 1.0):
            raise ValueError(f"delta must be in [0,1], got {self.delta}")
        if self.epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {self.epsilon}")
        if self.nprobe is not None and self.nprobe < 1:
            raise ValueError(f"nprobe must be >= 1, got {self.nprobe}")
        return self


EXACT = Guarantee()


def exact() -> Guarantee:
    return EXACT


def epsilon(eps: float) -> Guarantee:
    return Guarantee(epsilon=eps).validate()


def delta_epsilon(delta: float, eps: float = 0.0) -> Guarantee:
    return Guarantee(delta=delta, epsilon=eps).validate()


def ng(nprobe: int = 1) -> Guarantee:
    """Paper's ng-approximate: visit nprobe leaves, keep best-so-far."""
    return Guarantee(nprobe=nprobe).validate()


def joint_n_total(base_n_total: int, frozen_dead: int,
                  delta_live: int) -> int:
    """The row count N to evaluate r_delta against when the frozen
    store is served JOINTLY with a mutable delta tier
    (docs/INGEST.md).

    The live collection has ``base - frozen_dead + delta_live`` rows,
    but r_delta = F^{-1}(1 - delta^{1/N}) is DECREASING in N — a
    larger N SHRINKS the early-stop ball — so under-counting N
    (ignoring inserts) would stop early too often and break the delta
    guarantee, while over-counting (ignoring deletes) only tightens
    the stop radius and is conservative. Hence the joint
    N is the live count floored at the frozen N: inserts always raise
    it, deletes never lower it below what the frozen store was built
    for.
    """
    live = base_n_total - int(frozen_dead) + int(delta_live)
    return max(int(base_n_total), live, 1)


def effective_delta_after_loss(
    hist, kth_dists, n_lost: int, *, delta: float = 1.0,
    epsilon: float = 0.0,
) -> float:
    """The honest delta of an answer computed WITHOUT ``n_lost`` rows.

    A query that lost a shard past retries and replicas still returns
    the fold over the surviving shards — but the reported guarantee
    must account for the neighbors it never saw. Under the same
    independence model that defines r_delta (Ciaccia-Patella, §3.2.3:
    distances to the query are i.i.d. draws from the global
    distribution F persisted as ``hist``), the answer is
    epsilon-correct iff no unseen row improves the reported kth
    distance beyond the epsilon slack the guarantee already tolerates,
    i.e. no unseen row lies within ``d_k / (1 + epsilon)``. Each of
    the ``n_lost`` unseen rows misses that ball with probability
    ``1 - F(d_k / (1+eps))``, so per lane

        P[answer still epsilon-correct] = (1 - F(d_k/(1+eps)))**n_lost

    and the query-level delta is the prior ``delta`` times the WORST
    lane's survival probability (the guarantee must hold for every
    lane in the batch). ``kth_dists`` are the per-lane kth-best
    distances of the surviving fold (sqrt'd, same scale as ``hist``
    edges); an infinite kth (fewer than k survivors) yields delta 0 —
    no probabilistic claim survives an unfilled answer.
    """
    if n_lost <= 0:
        return float(delta)
    from .histogram import f_of
    d = np.asarray(kth_dists, np.float64).reshape(-1)
    d = d / (1.0 + float(epsilon))
    # F at the shrunk kth radius; inf radius -> F = 1 -> survival 0
    p_hit = np.where(np.isfinite(d),
                     np.asarray(f_of(hist, np.where(
                         np.isfinite(d), d, 0.0)), np.float64),
                     1.0)
    survival = np.power(np.clip(1.0 - p_hit, 0.0, 1.0), float(n_lost))
    return float(np.clip(float(delta) * survival.min(), 0.0, 1.0))
