"""QALSH (Huang et al. [71]) — query-aware LSH, delta-epsilon class.

The original keeps one B+-tree per hash line and performs a *query
anchored* bucket walk: buckets are defined at query time around h_i(q)
rather than by a pre-applied random shift, which is QALSH's accuracy
advantage over classical LSH. TPU adaptation (DESIGN.md §3 pattern): the
B+-trees become per-line SORTED projection arrays; the query-time walk
is a two-sided frontier expansion per line realized as a virtual merge
over precomputed rank offsets, and collision counting uses the sorted
positions directly. A point is a candidate once it collides on >= l of
the m lines (collision threshold); candidates are refined with true
distances in lb order of collision count. Early termination follows the
paper's beta-candidate budget and the chi^2-style guarantee check of
SRS is replaced by QALSH's own (c, l/m) condition, approximated here by
the delta-quantile stopping radius — the same histogram machinery as
Algorithm 2 (core/histogram.py), recorded as an adaptation.

As the paper notes (§5 "Practicality of QALSH"), a QALSH index targets
ONE (delta, epsilon) setting; we expose that trade-off explicitly: the
collision threshold l is fixed at build time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from ..search import SearchResult


@dataclasses.dataclass(frozen=True)
class QALSHIndex:
    proj: jax.Array      # [n, m] Gaussian lines
    sorted_vals: jax.Array  # [m, N] projections sorted per line
    sorted_ids: jax.Array   # [m, N] point ids in per-line sorted order
    data: jax.Array      # [N, n]
    m: int = dataclasses.field(metadata={"static": True})
    l_threshold: int = dataclasses.field(metadata={"static": True})
    n_total: int = dataclasses.field(metadata={"static": True})


jax.tree_util.register_dataclass(
    QALSHIndex,
    data_fields=["proj", "sorted_vals", "sorted_ids", "data"],
    meta_fields=["m", "l_threshold", "n_total"],
)


def build(data: np.ndarray, *, m: int = 8, l_threshold: Optional[int] =
          None, key=None) -> QALSHIndex:
    key = key if key is not None else jax.random.PRNGKey(3)
    n_pts, n = data.shape
    proj = jax.random.normal(key, (n, m), jnp.float32)
    feats = jnp.asarray(data, jnp.float32) @ proj  # [N, m]
    order = jnp.argsort(feats, axis=0)  # [N, m]
    sorted_vals = jnp.take_along_axis(feats, order, axis=0).T  # [m, N]
    sorted_ids = order.T.astype(jnp.int32)
    if l_threshold is None:
        l_threshold = max(1, int(round(0.6 * m)))
    return QALSHIndex(
        proj=proj, sorted_vals=sorted_vals, sorted_ids=sorted_ids,
        data=jnp.asarray(data, jnp.float32), m=m,
        l_threshold=l_threshold, n_total=n_pts,
    )


@functools.partial(jax.jit, static_argnames=("k", "steps", "frontier"))
def query(
    idx: QALSHIndex, queries: jax.Array, k: int, *,
    steps: int = 8, frontier: int = 64,
) -> SearchResult:
    """Frontier expansion: per line, take the `frontier` nearest
    projections around h_i(q) per step (two-sided), count collisions,
    refine points with >= l collisions. `steps` bounds the expansion
    (the beta budget); candidates are refined with true distances."""
    b, n = queries.shape
    npts = idx.n_total
    qf = queries.astype(jnp.float32)
    qp = qf @ idx.proj  # [B, m]

    # per line: rank position of the query in the sorted projections
    # searchsorted per line (m small static loop)
    centers = []
    for j in range(idx.m):
        centers.append(jnp.searchsorted(idx.sorted_vals[j], qp[:, j]))
    center = jnp.stack(centers, axis=1)  # [B, m]

    top_d = jnp.full((b, k), jnp.inf)
    top_i = jnp.full((b, k), -1, jnp.int32)
    scanned = jnp.zeros((b,), jnp.int32)
    counts = jnp.zeros((b, npts), jnp.int8)

    half = frontier // 2
    for step in range(steps):
        new_cand = []
        for j in range(idx.m):
            start = jnp.clip(center[:, j] - half * (step + 1),
                             0, npts - frontier * (step + 1))
            pos = start[:, None] + jnp.arange(frontier * (step + 1))
            pos = jnp.clip(pos, 0, npts - 1)
            ids_j = idx.sorted_ids[j][pos]  # [B, W]
            new_cand.append(ids_j)
        cand = jnp.concatenate(new_cand, axis=1)  # [B, m*W]
        cnt = jnp.zeros((b, npts), jnp.int8)
        cnt = cnt.at[jnp.arange(b)[:, None], cand].add(
            jnp.int8(1), mode="drop")
        counts = jnp.maximum(counts, cnt)  # collision count this radius
        hit = counts >= idx.l_threshold  # [B, N]
        # refine the frontier*m best-hit points this round
        sel_w = frontier * idx.m
        score = jnp.where(hit, counts.astype(jnp.float32), -1.0)
        _, sel = jax.lax.top_k(score, sel_w)  # [B, sel_w]
        rows = idx.data[sel]
        diff = rows - qf[:, None, :]
        d = jnp.sum(diff * diff, axis=-1)
        valid = jnp.take_along_axis(hit, sel, axis=1)
        d = jnp.where(valid, d, jnp.inf)
        top_d, top_i = ops.topk_merge(
            d, jnp.where(valid, sel.astype(jnp.int32), -1), top_d, top_i)
        scanned = scanned + valid.sum(axis=1).astype(jnp.int32)

    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(top_d, 0.0)),
        ids=top_i,
        leaves_visited=scanned,
        rows_scanned=scanned,
        lb_computed=jnp.int32(idx.m * npts),
    )
