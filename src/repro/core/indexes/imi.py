"""Inverted Multi-Index with (O)PQ codes (Babenko & Lempitsky [18],
Ge et al. OPQ [62]) — the paper's quantization-based competitor.

Two coarse codebooks over the vector halves define a Kc x Kc cell grid;
members are stored cell-contiguously with PQ codes of their residuals.
Query: coarse distances to both codebooks induce cell scores
du[u] + dv[v]; the nprobe best cells are scanned with per-cell residual
ADC tables (pq_adc kernel). Faithful to the paper's finding C4, IMI does
NOT re-rank on raw data — ADC distances are returned (an optional
``refine`` flag exists to quantify exactly that gap in the benchmarks).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from ..search import SearchResult
from ..summaries import pq as pq_mod


@dataclasses.dataclass(frozen=True)
class IMIIndex:
    u_cent: jax.Array        # [Kc, n/2]
    v_cent: jax.Array        # [Kc, n/2]
    cell_offsets: jax.Array  # [Kc*Kc + 1] int32
    codes: jax.Array         # [Npad, m] int32, cell-contiguous
    ids: jax.Array           # [Npad] int32 (-1 pad)
    data: jax.Array          # [Npad, n] cell-contiguous (refine only)
    pq_centroids: jax.Array  # [m, K, dsub] residual codebooks
    pq_rotation: jax.Array   # [n, n]
    kc: int = dataclasses.field(metadata={"static": True})
    m: int = dataclasses.field(metadata={"static": True})
    max_cell: int = dataclasses.field(metadata={"static": True})
    n_total: int = dataclasses.field(metadata={"static": True})


jax.tree_util.register_dataclass(
    IMIIndex,
    data_fields=["u_cent", "v_cent", "cell_offsets", "codes", "ids",
                 "data", "pq_centroids", "pq_rotation"],
    meta_fields=["kc", "m", "max_cell", "n_total"],
)


def build(
    data: np.ndarray,
    *,
    kc: int = 32,
    m: int = 16,
    k_pq: int = 256,
    kmeans_iters: int = 20,
    opq_iters: int = 0,
    train_size: Optional[int] = None,
    key=None,
) -> IMIIndex:
    n, d = data.shape
    assert d % 2 == 0 and d % m == 0
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    xd = jnp.asarray(data, jnp.float32)
    train = xd if train_size is None else xd[:train_size]
    half = d // 2
    u_cent = pq_mod.kmeans(k1, train[:, :half], kc, kmeans_iters)
    v_cent = pq_mod.kmeans(k2, train[:, half:], kc, kmeans_iters)

    du = ops.l2(xd[:, :half], u_cent)
    dv = ops.l2(xd[:, half:], v_cent)
    u = jnp.argmin(du, axis=1)
    v = jnp.argmin(dv, axis=1)
    cell = np.asarray(u * kc + v, np.int64)
    recon = jnp.concatenate([u_cent[u], v_cent[v]], axis=1)
    resid = xd - recon
    cb = pq_mod.pq_train(
        k3, resid if train_size is None else resid[:train_size],
        m, k_pq, kmeans_iters, opq_iters=opq_iters,
    )
    codes = np.asarray(pq_mod.pq_encode(cb, resid))

    order = np.argsort(cell, kind="stable")
    counts = np.bincount(cell, minlength=kc * kc)
    offsets = np.zeros(kc * kc + 1, np.int64)
    offsets[1:] = np.cumsum(counts)
    npad = n + 8
    pcodes = np.zeros((npad, m), np.int32)
    pcodes[:n] = codes[order]
    pids = np.full(npad, -1, np.int64)
    pids[:n] = order
    pdata = np.zeros((npad, d), np.float32)
    pdata[:n] = data[order]
    return IMIIndex(
        u_cent=u_cent, v_cent=v_cent,
        cell_offsets=jnp.asarray(offsets, jnp.int32),
        codes=jnp.asarray(pcodes, jnp.int32),
        ids=jnp.asarray(pids, jnp.int32),
        data=jnp.asarray(pdata, jnp.float32),
        pq_centroids=cb.centroids, pq_rotation=cb.rotation,
        kc=kc, m=m, max_cell=int(counts.max()), n_total=n,
    )


def query(
    idx: IMIIndex, queries: jax.Array, k: int, g=None, *,
    refine: bool = False, **legacy,
) -> SearchResult:
    """Guarantee-carrying entry point: IMI is an ng-only method
    (Table 1) — ``g`` must be an ng guarantee (``g.nprobe`` cells
    probed; default ng(16), the module's historical default). The
    loose ``nprobe=`` kwarg is the one-release deprecated shim
    (core/spec.py); delta/epsilon guarantees are rejected."""
    from ..spec import coerce_guarantee

    g = coerce_guarantee(g, legacy, caller="imi.query")
    if legacy:
        raise TypeError(
            f"imi.query() got unexpected keyword arguments "
            f"{sorted(legacy)}")
    if g.nprobe is None:
        if g.delta < 1.0 or g.epsilon > 0.0:
            raise ValueError("imi is ng-only: pass g=ng(nprobe), not "
                             "a delta/epsilon guarantee")
        nprobe = 16
    else:
        nprobe = g.nprobe
    return _query_impl(idx, queries, k, nprobe=nprobe, refine=refine)


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "refine"))
def _query_impl(
    idx: IMIIndex, queries: jax.Array, k: int, *, nprobe: int = 16,
    refine: bool = False,
) -> SearchResult:
    b, d = queries.shape
    half = d // 2
    kc = idx.kc
    qf = queries.astype(jnp.float32)
    du = ops.l2(qf[:, :half], idx.u_cent)  # [B, Kc]
    dv = ops.l2(qf[:, half:], idx.v_cent)
    scores = (du[:, :, None] + dv[:, None, :]).reshape(b, kc * kc)
    _, cells = jax.lax.top_k(-scores, nprobe)  # [B, nprobe] best cells

    c = idx.max_cell
    npad = idx.codes.shape[0]
    cb = pq_mod.PQCodebook(idx.pq_centroids, idx.pq_rotation)

    def step(carry, t):
        top_d, top_i, scanned = carry
        cell = cells[:, t]
        start = idx.cell_offsets[cell]
        end = idx.cell_offsets[cell + 1]
        gidx = start[:, None] + jnp.arange(c)[None, :]
        valid = gidx < end[:, None]
        gidx = jnp.minimum(gidx, npad - 1)
        codes_g = idx.codes[gidx]  # [B, C, m]
        ids_g = jnp.where(valid, idx.ids[gidx], -1)
        cu = idx.u_cent[cell // kc]
        cv = idx.v_cent[cell % kc]
        rq = qf - jnp.concatenate([cu, cv], axis=1)  # [B, n]
        lut = jax.vmap(lambda r: pq_mod.adc_lut(cb, r))(rq)  # [B, m, K]
        dist = jnp.take_along_axis(
            lut, codes_g.transpose(0, 2, 1), axis=2
        ).sum(axis=1)  # [B, C]
        if refine:
            rows = idx.data[gidx]
            diff = rows - qf[:, None, :]
            dist = jnp.sum(diff * diff, axis=-1)
        dist = jnp.where(valid, dist, jnp.inf)
        top_d, top_i = ops.topk_merge(dist, ids_g, top_d, top_i)
        return (top_d, top_i, scanned + valid.sum(axis=1)), None

    init = (jnp.full((b, k), jnp.inf), jnp.full((b, k), -1, jnp.int32),
            jnp.zeros((b,), jnp.int32))
    (top_d, top_i, scanned), _ = jax.lax.scan(
        step, init, jnp.arange(nprobe))
    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(top_d, 0.0)),
        ids=top_i,
        leaves_visited=jnp.full((b,), nprobe, jnp.int32),
        rows_scanned=scanned,
        lb_computed=jnp.int32(kc * kc),
    )
