"""Hierarchical proximity graph (HNSW, Malkov & Yashunin [103]) —
ng-approximate only, in-memory only, exactly as categorized in Table 1.

Structure: geometric level assignment (mL = 1/ln(M)); per level an
adjacency table [N, M] (non-members = -1 rows). Graph edges are the M
nearest members per level, computed with blocked device matmuls — i.e.
"HNSW with oracle neighbor selection"; the incremental-insertion
heuristic of the original is a CPU build-time approximation of exactly
this, so search behavior is representative while the build is
TPU-friendly (DESIGN.md §3). Search: greedy 1-NN descent through upper
levels, then beam search (efs) at level 0 with a packed visited bitmask.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from ..search import SearchResult

NEG = -1


@dataclasses.dataclass(frozen=True)
class GraphIndex:
    adj: jax.Array     # [levels, N, M] int32 neighbor ids, -1 padded
    data: jax.Array    # [N, n]
    entry: jax.Array   # scalar int32 entry node (top level member)
    levels: int = dataclasses.field(metadata={"static": True})
    m_links: int = dataclasses.field(metadata={"static": True})
    n_total: int = dataclasses.field(metadata={"static": True})


jax.tree_util.register_dataclass(
    GraphIndex, data_fields=["adj", "data", "entry"],
    meta_fields=["levels", "m_links", "n_total"],
)


def _knn_members(data: np.ndarray, members: np.ndarray, m: int,
                 block: int = 2048) -> np.ndarray:
    """[len(members), m] nearest member ids (global), blocked on device."""
    sub = jnp.asarray(data[members])
    out = []
    for s in range(0, len(members), block):
        q = sub[s:s + block]
        d = ops.l2(q, sub)
        # self-distance to +inf
        rows = np.arange(s, min(s + block, len(members)))
        d = d.at[jnp.arange(len(rows)), jnp.asarray(rows)].set(jnp.inf)
        _, idx = jax.lax.top_k(-d, min(m, len(members) - 1))
        out.append(np.asarray(idx))
    local = np.concatenate(out, axis=0)
    res = members[local]
    if res.shape[1] < m:  # tiny levels: pad
        pad = np.full((res.shape[0], m - res.shape[1]), NEG, np.int64)
        res = np.concatenate([res, pad], axis=1)
    return res


def build(
    data: np.ndarray, *, m_links: int = 16, key=None, max_levels: int = 5,
) -> GraphIndex:
    n = data.shape[0]
    rng = np.random.default_rng(0 if key is None else
                                int(jax.random.randint(key, (), 0, 2**31)))
    ml = 1.0 / np.log(max(m_links, 2))
    lvl = np.minimum(
        np.floor(-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int64),
        max_levels - 1,
    )
    levels = int(lvl.max()) + 1
    adj = np.full((levels, n, m_links), NEG, np.int64)
    for l in range(levels):
        members = np.where(lvl >= l)[0]
        if len(members) <= 1:
            continue
        adj[l, members] = _knn_members(data, members, m_links)
    top_members = np.where(lvl >= levels - 1)[0]
    entry = int(top_members[0]) if len(top_members) else 0
    return GraphIndex(
        adj=jnp.asarray(adj, jnp.int32),
        data=jnp.asarray(data, jnp.float32),
        entry=jnp.int32(entry),
        levels=levels, m_links=m_links, n_total=n,
    )


def _dist_to(qf, data, ids):
    rows = data[jnp.maximum(ids, 0)]
    diff = rows - qf[:, None, :] if rows.ndim == 3 else rows - qf
    return jnp.sum(diff * diff, axis=-1)


def _greedy_level(idx: GraphIndex, level: int, qf: jax.Array,
                  start: jax.Array, max_hops: int = 64):
    """Greedy 1-NN walk at one level. start [B] -> (node [B], hops [B])."""
    d0 = _dist_to(qf, idx.data, start)

    def cond(s):
        _, _, improved, hops = s
        return jnp.any(improved) & (hops < max_hops).all()

    def body(s):
        cur, cur_d, _, hops = s
        neigh = idx.adj[level, cur]  # [B, M]
        valid = neigh >= 0
        d = _dist_to(qf, idx.data, neigh)
        d = jnp.where(valid, d, jnp.inf)
        j = jnp.argmin(d, axis=1)
        bd = jnp.take_along_axis(d, j[:, None], 1)[:, 0]
        bi = jnp.take_along_axis(neigh, j[:, None], 1)[:, 0]
        improved = bd < cur_d
        cur = jnp.where(improved, bi, cur)
        cur_d = jnp.where(improved, bd, cur_d)
        return cur, cur_d, improved, hops + 1

    b = qf.shape[0]
    cur, cur_d, _, hops = jax.lax.while_loop(
        cond, body,
        (start, d0, jnp.ones((b,), bool), jnp.zeros((b,), jnp.int32)),
    )
    return cur, hops


@functools.partial(jax.jit, static_argnames=("k", "efs", "max_steps"))
def query(
    idx: GraphIndex, queries: jax.Array, k: int, *, efs: int = 64,
    max_steps: int = 0,
) -> SearchResult:
    b, n = queries.shape
    qf = queries.astype(jnp.float32)
    nn = idx.n_total
    words = (nn + 31) // 32
    efs = max(efs, k)  # the candidate list must hold k answers
    max_steps = max_steps or (4 * efs)

    # descend upper levels greedily
    cur = jnp.full((b,), idx.entry, jnp.int32)
    total_hops = jnp.zeros((b,), jnp.int32)
    for level in range(idx.levels - 1, 0, -1):
        cur, hops = _greedy_level(idx, level, qf, cur)
        total_hops = total_hops + hops

    # beam at level 0
    lanes = jnp.arange(b)
    ef = efs
    cand_d = jnp.full((b, ef), jnp.inf)
    cand_i = jnp.full((b, ef), -1, jnp.int32)
    expanded = jnp.zeros((b, ef), bool)
    visited = jnp.zeros((b, words), jnp.uint32)

    def mark(visited, nodes):  # nodes [B] (>=0)
        w = nodes // 32
        bit = jnp.uint32(1) << (nodes % 32).astype(jnp.uint32)
        return visited.at[lanes, w].set(visited[lanes, w] | bit)

    def is_visited(visited, nodes):  # [B, M]
        w = jnp.maximum(nodes, 0) // 32
        bit = jnp.uint32(1) << (jnp.maximum(nodes, 0) % 32).astype(
            jnp.uint32)
        got = jnp.take_along_axis(visited, w, axis=1)
        return (got & bit) > 0

    d0 = _dist_to(qf, idx.data, cur)
    cand_d = cand_d.at[:, 0].set(d0)
    cand_i = cand_i.at[:, 0].set(cur)
    visited = mark(visited, cur)

    def cond(s):
        _, _, _, _, active, steps, _ = s
        return jnp.any(active) & (steps < max_steps)

    def body(s):
        cand_d, cand_i, expanded, visited, active, steps, ndist = s
        unexp = (~expanded) & (cand_i >= 0)
        md = jnp.where(unexp, cand_d, jnp.inf)
        j = jnp.argmin(md, axis=1)  # [B]
        best_unexp = jnp.take_along_axis(md, j[:, None], 1)[:, 0]
        worst = cand_d[:, ef - 1]
        lane_active = active & (best_unexp < jnp.inf) \
            & (best_unexp <= worst)
        node = jnp.take_along_axis(cand_i, j[:, None], 1)[:, 0]
        expanded = expanded.at[lanes, j].set(
            expanded[lanes, j] | lane_active)
        neigh = idx.adj[0, jnp.maximum(node, 0)]  # [B, M]
        valid = (neigh >= 0) & lane_active[:, None] \
            & ~is_visited(visited, neigh)
        # mark all valid neighbors visited
        for col in range(idx.m_links):
            nd = jnp.where(valid[:, col], neigh[:, col], 0)
            w = nd // 32
            bit = jnp.where(
                valid[:, col],
                jnp.uint32(1) << (nd % 32).astype(jnp.uint32),
                jnp.uint32(0),
            )
            visited = visited.at[lanes, w].set(visited[lanes, w] | bit)
        d = _dist_to(qf, idx.data, neigh)
        d = jnp.where(valid, d, jnp.inf)
        ndist = ndist + valid.sum(axis=1).astype(jnp.int32)
        all_d = jnp.concatenate([cand_d, d], axis=1)
        all_i = jnp.concatenate([cand_i, jnp.where(valid, neigh, -1)],
                                axis=1)
        all_e = jnp.concatenate(
            [expanded, jnp.ones_like(d, bool) & False], axis=1)
        sd, si, se = jax.lax.sort((all_d, all_i, all_e), num_keys=1)
        return (sd[:, :ef], si[:, :ef], se[:, :ef], visited,
                lane_active, steps + 1, ndist)

    state = (cand_d, cand_i, expanded, visited,
             jnp.ones((b,), bool), jnp.zeros((), jnp.int32),
             jnp.zeros((b,), jnp.int32))
    cand_d, cand_i, expanded, visited, active, steps, ndist = \
        jax.lax.while_loop(cond, body, state)
    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(cand_d[:, :k], 0.0)),
        ids=cand_i[:, :k],
        leaves_visited=total_hops + steps,
        rows_scanned=ndist,
        lb_computed=jnp.int32(0),
    )
