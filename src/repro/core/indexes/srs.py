"""SRS (Sun et al. [142]) — tiny-index LSH via m Gaussian projections.

Points are examined in increasing *projected* distance (the paper's
incremental R-tree NN walk becomes a device argsort — the TPU adaptation;
same visit order, DESIGN.md §3). After each chunk of true-distance
refinements the early-termination test fires: since
proj_dist^2 / true_dist^2 ~ chi^2_m (2-stable projections),

    psi_m( p_cur^2 * (1+eps)^2 / bsf^2 ) >= delta

implies any point with true distance <= bsf/(1+eps) would already have
been seen with probability >= delta, so bsf is a delta-epsilon answer
(SRS early-termination condition, chi^2 CDF via gammainc). A max-scan
budget T' bounds the worst case exactly as in SRS.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from ..search import SearchResult
from ..summaries import randproj


@dataclasses.dataclass(frozen=True)
class SRSIndex:
    proj: jax.Array   # [n, m]
    feats: jax.Array  # [N, m] projected points
    data: jax.Array   # [N, n]
    m: int = dataclasses.field(metadata={"static": True})
    n_total: int = dataclasses.field(metadata={"static": True})


jax.tree_util.register_dataclass(
    SRSIndex, data_fields=["proj", "feats", "data"],
    meta_fields=["m", "n_total"],
)


def build(data: np.ndarray, *, m: int = 16, key=None) -> SRSIndex:
    key = key if key is not None else jax.random.PRNGKey(0)
    w = randproj.make_projection(key, data.shape[1], m)
    xd = jnp.asarray(data, jnp.float32)
    return SRSIndex(proj=w, feats=xd @ w, data=xd, m=m,
                    n_total=data.shape[0])


def query(
    idx: SRSIndex, queries: jax.Array, k: int, g=None, *,
    chunk: int = 256, max_scan: Optional[int] = None, **legacy,
) -> SearchResult:
    """Guarantee-carrying entry point: ``g`` is a
    :class:`repro.core.guarantees.Guarantee` (default: the module's
    historical delta-epsilon operating point, delta=0.95); loose
    ``delta=``/``epsilon=`` kwargs are the one-release deprecated shim
    (core/spec.py). SRS is a delta-epsilon method — ``g.nprobe`` is
    rejected (Table 1 categorization)."""
    from ..guarantees import Guarantee
    from ..spec import coerce_guarantee

    if g is None and not any(kw in legacy
                             for kw in ("delta", "epsilon", "nprobe")):
        g = Guarantee(delta=0.95)
    g = coerce_guarantee(g, legacy, caller="srs.query")
    if legacy:
        raise TypeError(
            f"srs.query() got unexpected keyword arguments "
            f"{sorted(legacy)}")
    if g.nprobe is not None:
        raise ValueError("srs is a delta-epsilon method: it has no "
                         "nprobe-bounded (ng) mode")
    return _query_impl(idx, queries, k, delta=g.delta,
                       epsilon=g.epsilon, chunk=chunk,
                       max_scan=max_scan)


@functools.partial(jax.jit,
                   static_argnames=("k", "chunk", "max_scan"))
def _query_impl(
    idx: SRSIndex, queries: jax.Array, k: int, *,
    delta: float = 0.95, epsilon: float = 0.0,
    chunk: int = 256, max_scan: Optional[int] = None,
) -> SearchResult:
    b, n = queries.shape
    nn = idx.n_total
    max_scan = min(max_scan or nn, nn)
    qf = queries.astype(jnp.float32)
    qp = qf @ idx.proj
    p_sq = ops.l2(qp, idx.feats)  # [B, N] projected squared dists
    order = jnp.argsort(p_sq, axis=1)
    p_sorted = jnp.take_along_axis(p_sq, order, axis=1)
    eps_mult = jnp.float32((1.0 + epsilon) ** 2)
    lanes = jnp.arange(b)

    def cond(s):
        return jnp.any(s[4])

    def body(s):
        ptr, top_d, top_i, scanned, active = s
        pos = ptr[:, None] + jnp.arange(chunk)[None, :]
        in_range = (pos < max_scan) & active[:, None]
        pos_c = jnp.minimum(pos, nn - 1)
        ids = jnp.take_along_axis(order, pos_c, axis=1)  # [B, C]
        rows = idx.data[ids]  # [B, C, n]
        diff = rows - qf[:, None, :]
        d = jnp.sum(diff * diff, axis=-1)
        d = jnp.where(in_range, d, jnp.inf)
        top_d, top_i = ops.topk_merge(
            d, jnp.where(in_range, ids, -1), top_d, top_i)
        scanned = scanned + in_range.sum(axis=1).astype(jnp.int32)
        ptr_next = jnp.minimum(ptr + chunk, max_scan)
        exhausted = ptr_next >= max_scan
        bsf = top_d[:, k - 1]
        p_cur = p_sorted[lanes, jnp.minimum(ptr_next, nn - 1)]
        arg = p_cur * eps_mult / jnp.maximum(bsf, 1e-30)
        early = randproj.psi(idx.m, arg) >= delta
        active = active & ~(exhausted | early)
        return ptr_next, top_d, top_i, scanned, active

    init = (jnp.zeros((b,), jnp.int32),
            jnp.full((b, k), jnp.inf),
            jnp.full((b, k), -1, jnp.int32),
            jnp.zeros((b,), jnp.int32),
            jnp.ones((b,), bool))
    _, top_d, top_i, scanned, _ = jax.lax.while_loop(cond, body, init)
    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(top_d, 0.0)),
        ids=top_i,
        leaves_visited=scanned,
        rows_scanned=scanned,
        lb_computed=jnp.int32(nn),
    )
