"""iSAX2+ index (Camerra et al. [33]) — build on host, search on device.

Build computes SAX words at base cardinality 2^bits on device (PAA kernel
+ breakpoint digitization), then grows the iSAX tree on host: a node whose
population exceeds leaf_cap deepens the cardinality of ONE segment by one
bit (iSAX 2.0's binary split), choosing the segment whose split is most
balanced — the bulk-loading-era splitting policy. Leaves freeze into
summary-space boxes: segment i at prefix length p covers the PAA interval
between breakpoints lo/hi of the prefix, exactly the MINDIST region; box
distance * sqrt(n/l) == MINDIST of the original paper.

`tighten=True` is a beyond-paper optimization (EXPERIMENTS.md §Perf):
boxes shrink to the min/max PAA of actual members — still a valid lower
bound (members' summaries lie inside), strictly tighter than the symbolic
region, so pruning improves with zero query-time cost.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


from ..histogram import DistanceHistogram, build_histogram
from ..index import FrozenIndex, freeze_from_leaves
from ..summaries import paa as paa_mod
from ..summaries import sax as sax_mod


def build(
    data: np.ndarray,
    *,
    n_segments: int = 16,
    bits: int = 8,
    leaf_cap: int = 512,
    tighten: bool = False,
    hist: Optional[DistanceHistogram] = None,
    key=None,
    data_dtype=np.float32,
) -> FrozenIndex:
    n, series_len = data.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    paa_np = np.asarray(paa_mod.transform(jnp.asarray(data), n_segments))
    breaks = sax_mod.breakpoints(1 << bits)
    codes = np.searchsorted(breaks, paa_np).astype(np.int32)  # [N, l]

    leaves: List[np.ndarray] = []
    leaf_prefix: List[np.ndarray] = []
    leaf_codes: List[np.ndarray] = []

    def split(members: np.ndarray, prefix_bits: np.ndarray,
              word: np.ndarray):
        if len(members) <= leaf_cap or prefix_bits.min() >= bits:
            leaves.append(members)
            leaf_prefix.append(prefix_bits.copy())
            leaf_codes.append(word.copy())
            return
        # candidate segments: those not yet at max cardinality
        best_seg, best_imb = -1, None
        mcodes = codes[members]
        for seg in range(n_segments):
            p = prefix_bits[seg]
            if p >= bits:
                continue
            bit = (mcodes[:, seg] >> (bits - p - 1)) & 1
            left = int((bit == 0).sum())
            imb = abs(2 * left - len(members))
            if best_imb is None or imb < best_imb:
                best_seg, best_imb = seg, imb
        seg = best_seg
        p = prefix_bits[seg]
        bit = (mcodes[:, seg] >> (bits - p - 1)) & 1
        for side in (0, 1):
            sub = members[bit == side]
            if len(sub) == 0:
                continue
            nb = prefix_bits.copy()
            nb[seg] = p + 1
            nw = word.copy()
            nw[seg] = (word[seg] << 1) | side
            split(sub, nb, nw)

    import sys

    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, 10000))
    try:
        split(np.arange(n), np.zeros(n_segments, np.int64),
              np.zeros(n_segments, np.int64))
    finally:
        sys.setrecursionlimit(old_limit)

    L = len(leaves)
    box_lo = np.zeros((L, n_segments), np.float32)
    box_hi = np.zeros((L, n_segments), np.float32)
    pb = sax_mod.padded_breakpoints(1 << bits)
    for li in range(L):
        pbits = leaf_prefix[li]
        word = leaf_codes[li]
        shift = bits - pbits
        lo_sym = word << shift
        hi_sym = lo_sym + (1 << shift)
        box_lo[li] = pb[lo_sym]
        box_hi[li] = pb[hi_sym]
        if tighten:
            mem = paa_np[leaves[li]]
            box_lo[li] = np.maximum(box_lo[li], mem.min(axis=0))
            box_hi[li] = np.minimum(box_hi[li], mem.max(axis=0))
    if hist is None:
        sample = data[np.random.default_rng(0).choice(
            n, min(n, 100_000), replace=False)]
        hist = build_histogram(sample, key)
    w = np.full(n_segments, series_len / n_segments, np.float32)
    return freeze_from_leaves(
        data, leaves, box_lo, box_hi, w, hist,
        data_dtype=data_dtype, kind="isax2+", summary="paa", n_summary=n_segments,
    )
