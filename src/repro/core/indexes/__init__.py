from . import dstree, graph, imi, isax, qalsh, srs, vafile

__all__ = ["dstree", "graph", "imi", "isax", "qalsh", "srs", "vafile"]
