"""VA+file (Ferhatosmanoglu et al. [57]) — DFT + adaptive scalar
quantization, skip-sequential search.

Build: orthonormal-DFT features (the paper's own KLT->DFT substitution),
per-dimension bit allocation by variance (the "+" of VA+file), per-dim
quantile boundaries (non-uniform quantizer), one cell per series. The
cell IS a summary-space box, so the unified search applies with
max_leaf=1 and leaf==series: the filter pass computes every cell's lower
bound (the VA-file sequential scan of approximations, vectorized) and
raw series are visited in lb order — the paper's nprobe semantics
("number of visited raw series") falls out as visit counting. Use
visit_batch >> 1 in search(); correctness is unaffected.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..histogram import DistanceHistogram, build_histogram
from ..index import FrozenIndex, freeze_from_leaves
from ..summaries import dft as dft_mod

_BIG = np.float32(1e9)


def allocate_bits(variances: np.ndarray, total_bits: int,
                  min_bits: int = 1, max_bits: int = 12) -> np.ndarray:
    """Greedy water-filling: each extra bit goes to the dim with the
    largest remaining per-bit variance reduction (var / 4^bits)."""
    l = len(variances)
    bits = np.full(l, min_bits, np.int64)
    remaining = total_bits - min_bits * l
    assert remaining >= 0, "bit budget below minimum"
    gain = variances / (4.0 ** bits)
    for _ in range(remaining):
        j = int(np.argmax(gain))
        if bits[j] >= max_bits:
            gain[j] = -np.inf
            continue
        bits[j] += 1
        gain[j] = variances[j] / (4.0 ** bits[j])
    return bits


def build(
    data: np.ndarray,
    *,
    n_coeffs: int = 16,
    bits_per_dim: int = 8,
    hist: Optional[DistanceHistogram] = None,
    key=None,
    data_dtype=np.float32,
) -> FrozenIndex:
    n, series_len = data.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    feats = np.asarray(dft_mod.transform(jnp.asarray(data), n_coeffs))
    variances = feats.var(axis=0) + 1e-12
    bits = allocate_bits(variances, bits_per_dim * n_coeffs)

    box_lo = np.zeros((n, n_coeffs), np.float32)
    box_hi = np.zeros((n, n_coeffs), np.float32)
    for d in range(n_coeffs):
        k = 1 << int(bits[d])
        qs = np.linspace(0.0, 1.0, k + 1)
        edges = np.quantile(feats[:, d], qs).astype(np.float32)
        edges = np.maximum.accumulate(edges)  # monotone under ties
        edges[0], edges[-1] = -_BIG, _BIG
        code = np.clip(np.searchsorted(edges, feats[:, d], side="right")
                       - 1, 0, k - 1)
        box_lo[:, d] = edges[code]
        box_hi[:, d] = edges[code + 1]

    if hist is None:
        sample = data[np.random.default_rng(0).choice(
            n, min(n, 100_000), replace=False)]
        hist = build_histogram(sample, key)
    leaves = [np.array([i]) for i in range(n)]
    w = np.asarray(dft_mod.weights(n_coeffs))
    return freeze_from_leaves(
        data, leaves, box_lo, box_hi, w, hist,
        data_dtype=data_dtype, kind="va+file", summary="dft", n_summary=n_coeffs,
    )
