"""DSTree (Wang et al. [152]) — EAPCA tree, host build / device search.

Every node summarizes its population per segment by (mean, std) ranges;
the lower bound is the weighted box distance over the 2l dims (validity
proof in summaries/eapca.py). Splitting follows the DSTree's spirit with
a simplification recorded in DESIGN.md §7: instead of dynamic vertical
re-segmentation we keep a fixed l-segmentation and split on the
(segment, statistic) pair with the largest weighted spread — the QoS
heuristic's dominant term — at the population median (balanced children,
which is also what the paper's bulk-loaded trees approximate). Leaf boxes
are tight member min/max ranges, as in the original DSTree.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..histogram import DistanceHistogram, build_histogram
from ..index import FrozenIndex, freeze_from_leaves
from ..summaries import eapca as eapca_mod


def build(
    data: np.ndarray,
    *,
    n_segments: int = 8,
    leaf_cap: int = 512,
    hist: Optional[DistanceHistogram] = None,
    key=None,
    data_dtype=np.float32,
) -> FrozenIndex:
    n, series_len = data.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    summ = np.asarray(eapca_mod.transform(jnp.asarray(data), n_segments))
    d2 = 2 * n_segments

    leaves: List[np.ndarray] = []

    stack = [np.arange(n)]
    while stack:
        members = stack.pop()
        if len(members) <= leaf_cap:
            leaves.append(members)
            continue
        s = summ[members]
        spread = s.max(axis=0) - s.min(axis=0)
        dim = int(np.argmax(spread))
        med = np.median(s[:, dim])
        left = s[:, dim] <= med
        # degenerate split (all equal): fall back to halving
        if left.all() or (~left).all():
            half = len(members) // 2
            stack.append(members[:half])
            stack.append(members[half:])
            continue
        stack.append(members[left])
        stack.append(members[~left])

    L = len(leaves)
    box_lo = np.zeros((L, d2), np.float32)
    box_hi = np.zeros((L, d2), np.float32)
    for li, mem in enumerate(leaves):
        s = summ[mem]
        box_lo[li] = s.min(axis=0)
        box_hi[li] = s.max(axis=0)
    if hist is None:
        sample = data[np.random.default_rng(0).choice(
            n, min(n, 100_000), replace=False)]
        hist = build_histogram(sample, key)
    w = np.asarray(eapca_mod.weights(series_len, n_segments))
    return freeze_from_leaves(
        data, leaves, box_lo, box_hi, w, hist,
        data_dtype=data_dtype, kind="dstree", summary="eapca", n_summary=n_segments,
    )
