"""Typed build/open specs: the engine's configuration surface.

``DistributedEngine.build`` grew one keyword at a time — ``spill_dir``,
``codec``, ``keep_resident``, ``replicas``, plus ``**params`` silently
forwarded to whichever index builder the engine was constructed with.
The streaming-ingest tier (docs/INGEST.md) would have added four more
knobs to that sprawl, so the surface is redesigned around two frozen
dataclasses:

  IndexSpec   WHAT to build: the method and its per-method build
              params (leaf_cap and friends) — everything that shapes
              the frozen artifact.
  StoreSpec   WHERE and HOW to serve it: spill directory, leaf codec,
              residency, replica count, and the delta-tier /
              compaction knobs that govern writes at serving time.

Old kwarg spellings keep working for one release through a shim that
constructs the spec and emits :class:`APIDeprecationWarning`
(``scripts/verify.sh`` turns it into an error, mirroring the v1-store
format precedent, so the repo's own callers can never regress onto the
deprecated surface). The same warning class covers the OTHER redesign
riding this release: ``search`` / ``search_ooc`` take a
:class:`repro.core.guarantees.Guarantee` object instead of loose
``delta=``/``epsilon=``/``nprobe=`` kwargs (the ``guarantee-kwargs``
analysis rule fails in-repo callers still on the loose spelling —
docs/ANALYSIS.md).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Mapping, Optional, Tuple


class APIDeprecationWarning(DeprecationWarning):
    """Emitted by the one-release back-compat shims: loose build/open
    kwargs instead of IndexSpec/StoreSpec, and loose delta/epsilon/
    nprobe kwargs instead of a Guarantee. An error under
    scripts/verify.sh."""


def _warn(msg: str, stacklevel: int = 3) -> None:
    warnings.warn(msg, APIDeprecationWarning, stacklevel=stacklevel)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """What to build: a method name plus its per-method build params
    (forwarded verbatim to the builder — e.g. ``leaf_cap`` for the
    tree methods). ``params`` is stored as a sorted item tuple so the
    spec stays hashable/frozen; read it back via :attr:`build_params`.
    """

    method: str = "dstree"
    params: Tuple[Tuple[str, Any], ...] = ()

    def __init__(self, method: str = "dstree",
                 params: Optional[Mapping[str, Any]] = None, **kw):
        object.__setattr__(self, "method", method)
        merged = dict(params or {})
        merged.update(kw)
        object.__setattr__(self, "params",
                           tuple(sorted(merged.items())))

    @property
    def build_params(self) -> Dict[str, Any]:
        return dict(self.params)


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """Where/how the built shards are served, plus the mutable-tier
    knobs (docs/INGEST.md):

      spill_dir        persist every shard as an on-disk store (and
                       host the compacted delta segments under
                       ``spill_dir/segments/``); None = resident only.
      codec            leaf payload encoding ("f32"/"bf16"/"pq",
                       store format v2) for shards AND segments.
      keep_resident    stack the shards into HBM (False requires
                       spill_dir: pure out-of-core serving).
      replicas         on-disk copies per shard (failover,
                       docs/FAULT.md).
      delta_max_rows   live delta rows at which auto-compaction
                       triggers (writes always succeed; this bounds
                       the brute-scanned tier, not the write rate).
      auto_compact     run the background compaction daemon
                       (engine.enable_writes starts it; a manual
                       ``engine.compact()`` works either way).
      compact_interval_s  daemon poll period between threshold checks.
    """

    spill_dir: Optional[str] = None
    codec: str = "f32"
    keep_resident: bool = True
    replicas: int = 1
    delta_max_rows: int = 8192
    auto_compact: bool = False
    compact_interval_s: float = 0.05

    def validate(self) -> "StoreSpec":
        if self.replicas < 1:
            raise ValueError(
                f"replicas must be >= 1, got {self.replicas}")
        if self.replicas > 1 and self.spill_dir is None:
            raise ValueError("replicas > 1 requires spill_dir")
        if not self.keep_resident and self.spill_dir is None:
            raise ValueError("keep_resident=False requires spill_dir")
        if self.delta_max_rows < 1:
            raise ValueError(
                f"delta_max_rows must be >= 1, got {self.delta_max_rows}")
        return self


# kwargs the old build() signature consumed itself; everything else in
# **legacy was builder params (IndexSpec territory)
_LEGACY_STORE_KEYS = ("spill_dir", "codec", "keep_resident", "replicas")


def coerce_build_args(
    method: str,
    index: Optional[IndexSpec],
    store: Optional[StoreSpec],
    legacy: Dict[str, Any],
) -> Tuple[IndexSpec, StoreSpec]:
    """Resolve ``build(data, index=..., store=...)`` against the
    deprecated kwarg spelling. Specs win; any legacy kwarg present
    emits :class:`APIDeprecationWarning` and is folded into the spec
    it belongs to. Mixing a spec with legacy kwargs for the SAME spec
    is an error (ambiguous precedence)."""
    store_kw = {k: legacy.pop(k) for k in _LEGACY_STORE_KEYS
                if k in legacy}
    if legacy and index is not None:
        raise TypeError(
            f"build(): both index=IndexSpec and loose builder params "
            f"{sorted(legacy)} — put the params in the IndexSpec")
    if store_kw and store is not None:
        raise TypeError(
            f"build(): both store=StoreSpec and loose store kwargs "
            f"{sorted(store_kw)} — put them in the StoreSpec")
    if store_kw or legacy:
        _warn(
            "build(spill_dir=/codec=/keep_resident=/replicas=/"
            "**builder_params) is deprecated: pass "
            "index=IndexSpec(method, params) and store=StoreSpec(...) "
            "(docs/INGEST.md migration guide)", stacklevel=4)
    if index is None:
        index = IndexSpec(method=method, params=legacy)
    if store is None:
        store = StoreSpec(**store_kw)
    return index, store.validate()


def coerce_store_spec(store, *, method: Optional[str] = None,
                      index: Optional[IndexSpec] = None
                      ) -> Tuple[IndexSpec, StoreSpec]:
    """Resolve ``open_spill``'s first argument: a StoreSpec (new), or
    a bare spill-dir string (deprecated shim). ``method=`` (the old
    kwarg) is deprecated in favor of ``index=IndexSpec(method=...)``.
    """
    if index is not None and method is not None:
        raise TypeError("open_spill(): pass index=IndexSpec(...) OR "
                        "the deprecated method=, not both")
    if method is not None:
        _warn("open_spill(method=...) is deprecated: pass "
              "index=IndexSpec(method=...)", stacklevel=4)
        index = IndexSpec(method=method)
    if index is None:
        index = IndexSpec()
    if isinstance(store, StoreSpec):
        if store.spill_dir is None:
            raise ValueError("open_spill(StoreSpec): spill_dir is "
                             "required")
        return index, store.validate()
    if isinstance(store, str):
        _warn("open_spill(spill_dir_str) is deprecated: pass a "
              "StoreSpec(spill_dir=...) (docs/INGEST.md migration "
              "guide)", stacklevel=4)
        return index, StoreSpec(spill_dir=store,
                                keep_resident=False).validate()
    raise TypeError(f"open_spill(): expected StoreSpec or str, got "
                    f"{type(store).__name__}")


def coerce_guarantee(g, kw: Dict[str, Any], *, caller: str):
    """Resolve a search entry point's guarantee: ``g`` (a Guarantee,
    new spelling) or loose ``delta=``/``epsilon=``/``nprobe=`` kwargs
    popped from ``kw`` (deprecated shim). Mutates ``kw`` (pops the
    loose keys) and returns the Guarantee."""
    from .guarantees import Guarantee

    loose = {key: kw.pop(key) for key in ("delta", "epsilon", "nprobe")
             if key in kw}
    if g is not None:
        if loose:
            raise TypeError(
                f"{caller}(): both a Guarantee and loose "
                f"{sorted(loose)} kwargs — pass only the Guarantee")
        return g.validate()
    if loose:
        _warn(
            f"{caller}(delta=/epsilon=/nprobe=) is deprecated: pass "
            f"g=Guarantee(...) (core.guarantees constructors; "
            "docs/INGEST.md migration guide)", stacklevel=4)
        return Guarantee(
            delta=loose.get("delta", 1.0),
            epsilon=loose.get("epsilon", 0.0),
            nprobe=loose.get("nprobe"),
        ).validate()
    return Guarantee()
