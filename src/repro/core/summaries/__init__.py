from . import dft, eapca, paa, pq, randproj, sax

__all__ = ["dft", "eapca", "paa", "pq", "randproj", "sax"]
