"""Product quantization + OPQ (Jegou et al. [74], Ge et al. [62]).

k-means, PQ training/encoding, ADC lookup tables, and OPQ's alternating
rotation optimization (orthogonal Procrustes via SVD). All device-side
JAX; IMI (core/indexes/imi.py) composes these into the inverted
multi-index.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key, x: jax.Array, k: int, iters: int = 25) -> jax.Array:
    """Lloyd's k-means. x [N, d] -> centroids [k, d] (f32).

    Empty clusters are re-seeded on random points each iteration.
    """
    n = x.shape[0]
    xf = x.astype(jnp.float32)
    init = jax.random.choice(key, n, (k,), replace=False)
    cent = xf[init]

    def step(carry, key_i):
        cent = carry
        d = ops.l2(xf, cent)  # [N, k]
        assign = jnp.argmin(d, axis=1)
        one = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # [N, k]
        counts = one.sum(axis=0)  # [k]
        sums = one.T @ xf  # [k, d]
        newc = sums / jnp.maximum(counts[:, None], 1.0)
        # reseed empties
        rnd = jax.random.choice(key_i, n, (k,))
        newc = jnp.where(counts[:, None] > 0, newc, xf[rnd])
        return newc, None

    keys = jax.random.split(key, iters)
    cent, _ = jax.lax.scan(step, cent, keys)
    return cent


class PQCodebook(NamedTuple):
    centroids: jax.Array  # [m, K, d_sub]
    rotation: jax.Array   # [d, d] (identity for plain PQ)


def pq_train(
    key, x: jax.Array, m: int, k: int = 256, iters: int = 20,
    opq_iters: int = 0,
) -> PQCodebook:
    """Train PQ (opq_iters=0) or OPQ (alternating rotation/codebooks)."""
    n, d = x.shape
    assert d % m == 0
    dsub = d // m
    rot = jnp.eye(d, dtype=jnp.float32)
    xf = x.astype(jnp.float32)

    def train_codebooks(xr, key):
        keys = jax.random.split(key, m)
        cents = []
        for j in range(m):
            sub = xr[:, j * dsub:(j + 1) * dsub]
            cents.append(kmeans(keys[j], sub, k, iters))
        return jnp.stack(cents)  # [m, K, dsub]

    cents = train_codebooks(xf @ rot, key)
    for _it in range(opq_iters):
        codes = pq_encode(PQCodebook(cents, rot), x)
        recon = pq_reconstruct(PQCodebook(cents, jnp.eye(d)), codes)
        # Procrustes: R = argmin ||X R - recon||_F  =>  R = U V^T
        u, _, vt = jnp.linalg.svd(xf.T @ recon, full_matrices=False)
        rot = u @ vt
        key, sub = jax.random.split(key)
        cents = train_codebooks(xf @ rot, sub)
    return PQCodebook(cents, rot)


def pq_encode(cb: PQCodebook, x: jax.Array) -> jax.Array:
    """[N, d] -> [N, m] int32 codes."""
    xf = x.astype(jnp.float32) @ cb.rotation
    m, k, dsub = cb.centroids.shape
    codes = []
    for j in range(m):
        sub = xf[:, j * dsub:(j + 1) * dsub]
        d = ops.l2(sub, cb.centroids[j])
        codes.append(jnp.argmin(d, axis=1).astype(jnp.int32))
    return jnp.stack(codes, axis=1)


def pq_reconstruct(cb: PQCodebook, codes: jax.Array) -> jax.Array:
    m = codes.shape[1]
    parts = [jnp.take(cb.centroids[j], codes[:, j], axis=0)
             for j in range(m)]
    recon = jnp.concatenate(parts, axis=1)
    return recon @ cb.rotation.T


def adc_lut(cb: PQCodebook, q: jax.Array) -> jax.Array:
    """Per-subspace squared-distance tables for one query: [m, K]."""
    qf = q.astype(jnp.float32) @ cb.rotation
    m, k, dsub = cb.centroids.shape
    qs = qf.reshape(m, dsub)
    diff = cb.centroids - qs[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def adc_lut_batch(cb: PQCodebook, q: jax.Array) -> jax.Array:
    """Per-subspace squared-distance tables for a query batch:
    [B, n] -> [B, m, K] (vmapped :func:`adc_lut`)."""
    return jax.vmap(lambda qq: adc_lut(cb, qq))(q)


def adc_scan(cb: PQCodebook, codes: jax.Array, q: jax.Array,
             **kw) -> jax.Array:
    """Asymmetric distances of all codes to one query: [N]."""
    return ops.pq_adc(codes, adc_lut(cb, q), **kw)
