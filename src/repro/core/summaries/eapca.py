"""Extended APCA summaries for the DSTree (Wang et al. [152]).

Each segment of width w is summarized by (mean, std). The node lower
bound used by the DSTree is the weighted box distance over the 2l dims
[mean_1..mean_l, std_1..std_l] with weight w per dim, valid because

  sum_j (q_j - s_j)^2  =  w (mu_q - mu_s)^2 + || q~ - s~ ||^2
                       >= w (mu_q - mu_s)^2 + (||q~|| - ||s~||)^2
                       =  w (mu_q - mu_s)^2 + w (sigma_q - sigma_s)^2

(reverse triangle inequality on the centered segments; sigma is the
population std). Property-tested in tests/test_summaries.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def transform(x: jax.Array, n_segments: int) -> jax.Array:
    """[N, n] -> [N, 2l]: concat(segment means, segment stds), f32."""
    n = x.shape[-1]
    assert n % n_segments == 0
    w = n // n_segments
    seg = x.reshape(x.shape[:-1] + (n_segments, w)).astype(jnp.float32)
    mean = seg.mean(axis=-1)
    std = seg.std(axis=-1)  # population (ddof=0) — required for the bound
    return jnp.concatenate([mean, std], axis=-1)


def weights(series_len: int, n_segments: int) -> jax.Array:
    w = series_len / n_segments
    return jnp.full((2 * n_segments,), w, jnp.float32)
