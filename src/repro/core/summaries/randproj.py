"""Gaussian random projections (SRS, Sun et al. [142]).

2-stable projections: for w_i ~ N(0, I_n), <u, w_i> ~ N(0, ||u||^2), so
||proj(u)||^2 / ||u||^2 ~ chi^2_m. SRS's early-termination test uses the
chi^2 CDF psi_m, implemented with the regularized lower incomplete gamma
(jax.scipy.special.gammainc) — no scipy dependency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammainc


def make_projection(key, series_len: int, m: int) -> jax.Array:
    """[n, m] Gaussian matrix (unscaled, 2-stable)."""
    return jax.random.normal(key, (series_len, m), jnp.float32)


def transform(x: jax.Array, w: jax.Array) -> jax.Array:
    return x.astype(jnp.float32) @ w


def psi(m: int, x: jax.Array) -> jax.Array:
    """chi^2_m CDF."""
    return gammainc(m / 2.0, jnp.maximum(x, 0.0) / 2.0)
