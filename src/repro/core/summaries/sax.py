"""SAX / iSAX symbolization (Lin et al. [94], Shieh & Keogh [137]).

PAA values are quantized against N(0,1) breakpoints (data series are
z-normalized, so standard-normal quantiles are the canonical choice; the
breakpoints can also be fit from data). iSAX compares words of different
cardinalities by bit-prefix: a node at prefix length p over segment i
covers the PAA interval [breaks[sym<<(b-p)], breaks[(sym+1)<<(b-p)]] —
those intervals are exactly the boxes handed to the unified box-mindist
lower bound (kernels/box_mindist.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import ndtri

from . import paa as paa_mod


def breakpoints(cardinality: int) -> np.ndarray:
    """Interior breakpoints of N(0,1): length cardinality-1, ascending."""
    qs = np.arange(1, cardinality) / cardinality
    return np.asarray(ndtri(jnp.asarray(qs)), np.float64)


def padded_breakpoints(cardinality: int, span: float = 1e9) -> np.ndarray:
    """[-inf, b_1..b_{a-1}, +inf] with finite sentinels (length a+1)."""
    b = breakpoints(cardinality)
    return np.concatenate([[-span], b, [span]])


def encode(
    x: jax.Array, n_segments: int, cardinality: int
) -> jax.Array:
    """SAX words at full cardinality. [N, n] -> [N, l] int32 symbols."""
    p = paa_mod.transform(x, n_segments)
    b = jnp.asarray(breakpoints(cardinality), jnp.float32)
    return jnp.searchsorted(b, p.astype(jnp.float32)).astype(jnp.int32)


def prefix_box(
    symbols: np.ndarray,  # [l] full-cardinality symbols
    prefix_bits: np.ndarray,  # [l] per-segment prefix length in bits
    total_bits: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """PAA-space interval covered by an iSAX word prefix (per segment)."""
    card = 1 << total_bits
    pb = padded_breakpoints(card)
    shift = total_bits - prefix_bits
    lo_sym = (symbols >> shift) << shift
    hi_sym = lo_sym + (1 << shift)
    return pb[lo_sym], pb[hi_sym]
