"""Piecewise Aggregate Approximation (Keogh et al. [82]).

The lower-bounding contract (property-tested):
    (n/l) * || paa(Q) - paa(S) ||^2  <=  || Q - S ||^2
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def transform(x: jax.Array, n_segments: int, **kw) -> jax.Array:
    """[.., n] -> [.., l] segment means (f32)."""
    if x.ndim == 1:
        return ops.paa(x[None], n_segments, **kw)[0]
    return ops.paa(x, n_segments, **kw)


def weights(series_len: int, n_segments: int) -> jax.Array:
    """Per-dim weight in the box lower bound: segment width n/l."""
    w = series_len / n_segments
    return jnp.full((n_segments,), w, jnp.float32)
