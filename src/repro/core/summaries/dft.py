"""DFT summarization for the VA+file (Ferhatosmanoglu et al. [57]).

The paper replaced the original KLT with DFT for efficiency; we follow.
With the orthonormal rFFT of a real series (n even):

  ||x||^2 = c_0^2 + sum_{1<=j<n/2} 2(re_j^2 + im_j^2) + c_{n/2}^2

so the feature layout [c0, sqrt2*re_1, sqrt2*im_1, sqrt2*re_2, ...]
is an isometry prefix: truncating to the first l features lower-bounds
the true distance (Parseval). Property-tested.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def transform(x: jax.Array, n_coeffs: int) -> jax.Array:
    """[N, n] -> [N, l] energy-preserving DFT features (f32)."""
    n = x.shape[-1]
    c = jnp.fft.rfft(x.astype(jnp.float32), axis=-1, norm="ortho")
    parts = [c[..., 0].real[..., None]]
    nyq = n // 2
    re = c[..., 1:nyq].real * jnp.sqrt(2.0)
    im = c[..., 1:nyq].imag * jnp.sqrt(2.0)
    inter = jnp.stack([re, im], axis=-1).reshape(x.shape[:-1] + (-1,))
    parts.append(inter)
    if n % 2 == 0:
        parts.append(c[..., nyq].real[..., None])
    feats = jnp.concatenate(parts, axis=-1)
    return feats[..., :n_coeffs]


def weights(n_coeffs: int) -> jax.Array:
    """DFT features are already isometric — unit weights."""
    return jnp.ones((n_coeffs,), jnp.float32)
