"""Distance-distribution estimation and the r_delta stopping radius.

Following Ciaccia & Patella [43, 45] as the paper does (§3.2.3): estimate
the overall pairwise distance distribution F(.) from a sample (the paper
uses density histograms on a 100K-series sample), then

    r_delta = sup { r : P[no point within r of Q] >= delta }
            = F^{-1}( 1 - delta^(1/N) )

under the independence approximation P[B(Q, r) empty] = (1 - F(r))^N.
The histogram is a pytree (edges + cdf) so it shards/replicates cleanly
and ships inside the FrozenIndex.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class DistanceHistogram(NamedTuple):
    edges: jax.Array  # [n_bins+1] ascending distance values
    cdf: jax.Array    # [n_bins+1] F(edges), cdf[0]=0, cdf[-1]=1


def build_histogram(
    data: np.ndarray, key, n_pairs: int = 100_000, n_bins: int = 512
) -> DistanceHistogram:
    """Empirical F from random pairs of the sample (paper: 100K sample)."""
    n = data.shape[0]
    rng = np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))
    i = rng.integers(0, n, n_pairs)
    j = rng.integers(0, n, n_pairs)
    keep = i != j
    d = np.linalg.norm(data[i[keep]] - data[j[keep]], axis=1)
    qs = np.linspace(0.0, 1.0, n_bins + 1)
    edges = np.quantile(d, qs)
    edges[0] = 0.0
    return DistanceHistogram(
        edges=jnp.asarray(edges, jnp.float32),
        cdf=jnp.asarray(qs, jnp.float32),
    )


def f_of(hist: DistanceHistogram, r: jax.Array) -> jax.Array:
    """F(r) by linear interpolation."""
    return jnp.interp(r, hist.edges, hist.cdf, left=0.0, right=1.0)


def f_inverse(hist: DistanceHistogram, p: jax.Array) -> jax.Array:
    """F^{-1}(p) by inverse interpolation."""
    return jnp.interp(p, hist.cdf, hist.edges)


def r_delta(hist: DistanceHistogram, delta: float, n_total: int
            ) -> jax.Array:
    """The paper's delta radius (scalar, f32). delta=1 -> 0 (no early
    stop, Algorithm 2 degenerates to epsilon-approximate)."""
    delta = jnp.asarray(delta, jnp.float32)
    p = 1.0 - jnp.power(jnp.maximum(delta, 1e-30), 1.0 / float(n_total))
    r = f_inverse(hist, p)
    return jnp.where(delta >= 1.0, 0.0, r)
